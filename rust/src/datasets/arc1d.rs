//! Procedural 1D-ARC task generators — all 18 task types of Table 2.
//!
//! The real 1D-ARC dataset (Xu et al. 2024) is not redistributable here;
//! these generators produce train/test splits for the same 18 task names
//! with the same structure: rows of colored pixels (0 = background, 1-9 =
//! colors), a deterministic input -> target transformation per task, and
//! disjoint seeds between splits so solving the test set requires learning
//! the *rule*, not memorizing examples (DESIGN.md §3).
//!
//! Conventions shared by every generator: block = maximal run of a single
//! non-background color; generated examples always fit the row with at
//! least one background cell of margin where the task needs room to move.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub const NUM_COLORS: usize = 10; // 0 = background + 9 palette colors

/// One input/target example.
#[derive(Clone, Debug, PartialEq)]
pub struct Example {
    pub input: Vec<u8>,
    pub target: Vec<u8>,
}

/// The 18 task types of paper Table 2, in its row order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    Move1,
    Move2,
    Move3,
    MoveDynamic,
    Move2Towards,
    Fill,
    PaddedFill,
    Hollow,
    Flip,
    Mirror,
    Denoise,
    DenoiseMulticolor,
    PatternCopy,
    PatternCopyMulticolor,
    RecolorOddEven,
    RecolorSize,
    RecolorSizeCmp,
    Scaling,
}

impl Task {
    pub const ALL: [Task; 18] = [
        Task::Move1,
        Task::Move2,
        Task::Move3,
        Task::MoveDynamic,
        Task::Move2Towards,
        Task::Fill,
        Task::PaddedFill,
        Task::Hollow,
        Task::Flip,
        Task::Mirror,
        Task::Denoise,
        Task::DenoiseMulticolor,
        Task::PatternCopy,
        Task::PatternCopyMulticolor,
        Task::RecolorOddEven,
        Task::RecolorSize,
        Task::RecolorSizeCmp,
        Task::Scaling,
    ];

    /// Look up a task by its Table-2 label, case-insensitively, with
    /// spaces or dashes (`"Move 1"`, `"move-1"`). The single parser
    /// behind every `--task` CLI/example flag.
    pub fn find(name: &str) -> Option<Task> {
        Task::ALL.iter().copied().find(|t| {
            t.name().eq_ignore_ascii_case(name)
                || t.name().to_lowercase().replace(' ', "-")
                    == name.to_lowercase()
        })
    }

    /// The dashed lowercase form [`Task::find`] accepts (`"move-1"`).
    pub fn slug(&self) -> String {
        self.name().to_lowercase().replace(' ', "-")
    }

    /// Paper Table 2 row label.
    pub fn name(&self) -> &'static str {
        match self {
            Task::Move1 => "Move 1",
            Task::Move2 => "Move 2",
            Task::Move3 => "Move 3",
            Task::MoveDynamic => "Move Dynamic",
            Task::Move2Towards => "Move 2 Towards",
            Task::Fill => "Fill",
            Task::PaddedFill => "Padded Fill",
            Task::Hollow => "Hollow",
            Task::Flip => "Flip",
            Task::Mirror => "Mirror",
            Task::Denoise => "Denoise",
            Task::DenoiseMulticolor => "Denoise Multicolor",
            Task::PatternCopy => "Pattern Copy",
            Task::PatternCopyMulticolor => "Pattern Copy Multicolor",
            Task::RecolorOddEven => "Recolor by Odd Even",
            Task::RecolorSize => "Recolor by Size",
            Task::RecolorSizeCmp => "Recolor by Size Comparison",
            Task::Scaling => "Scaling",
        }
    }

    /// GPT-4 direct-grid accuracy (%), copied from the paper's Table 2
    /// (itself from Xu et al. 2024 Appendix A).
    pub fn gpt4_accuracy(&self) -> f64 {
        match self {
            Task::Move1 => 66.0,
            Task::Move2 => 26.0,
            Task::Move3 => 24.0,
            Task::MoveDynamic => 22.0,
            Task::Move2Towards => 34.0,
            Task::Fill => 66.0,
            Task::PaddedFill => 26.0,
            Task::Hollow => 56.0,
            Task::Flip => 70.0,
            Task::Mirror => 20.0,
            Task::Denoise => 36.0,
            Task::DenoiseMulticolor => 60.0,
            Task::PatternCopy => 36.0,
            Task::PatternCopyMulticolor => 38.0,
            Task::RecolorOddEven => 32.0,
            Task::RecolorSize => 28.0,
            Task::RecolorSizeCmp => 20.0,
            Task::Scaling => 88.0,
        }
    }

    /// NCA accuracy (%) the paper reports (Table 2), for shape comparison.
    pub fn paper_nca_accuracy(&self) -> f64 {
        match self {
            Task::Move1 => 100.0,
            Task::Move2 => 100.0,
            Task::Move3 => 100.0,
            Task::MoveDynamic => 12.0,
            Task::Move2Towards => 98.0,
            Task::Fill => 66.0,
            Task::PaddedFill => 28.0,
            Task::Hollow => 98.0,
            Task::Flip => 28.0,
            Task::Mirror => 6.0,
            Task::Denoise => 100.0,
            Task::DenoiseMulticolor => 58.0,
            Task::PatternCopy => 100.0,
            Task::PatternCopyMulticolor => 100.0,
            Task::RecolorOddEven => 0.0,
            Task::RecolorSize => 0.0,
            Task::RecolorSizeCmp => 0.0,
            Task::Scaling => 88.0,
        }
    }

    /// Generate one example on a row of `width` cells.
    pub fn generate(&self, width: usize, rng: &mut Rng) -> Example {
        assert!(width >= 16, "1D-ARC rows need width >= 16, got {width}");
        match self {
            Task::Move1 => gen_move(width, 1, rng),
            Task::Move2 => gen_move(width, 2, rng),
            Task::Move3 => gen_move(width, 3, rng),
            Task::MoveDynamic => gen_move_dynamic(width, rng),
            Task::Move2Towards => gen_move_towards(width, rng),
            Task::Fill => gen_fill(width, rng),
            Task::PaddedFill => gen_padded_fill(width, rng),
            Task::Hollow => gen_hollow(width, rng),
            Task::Flip => gen_flip(width, rng),
            Task::Mirror => gen_mirror(width, rng),
            Task::Denoise => gen_denoise(width, false, rng),
            Task::DenoiseMulticolor => gen_denoise(width, true, rng),
            Task::PatternCopy => gen_pattern_copy(width, false, rng),
            Task::PatternCopyMulticolor => gen_pattern_copy(width, true, rng),
            Task::RecolorOddEven => gen_recolor_odd_even(width, rng),
            Task::RecolorSize => gen_recolor_size(width, rng),
            Task::RecolorSizeCmp => gen_recolor_size_cmp(width, rng),
            Task::Scaling => gen_scaling(width, rng),
        }
    }

    /// A train/test split with disjoint RNG streams.
    pub fn dataset(&self, width: usize, train: usize, test: usize,
                   seed: u64) -> (Vec<Example>, Vec<Example>) {
        let mut train_rng = Rng::new(seed).fold_in(0xA11CE);
        let mut test_rng = Rng::new(seed).fold_in(0xB0B);
        let train_set =
            (0..train).map(|_| self.generate(width, &mut train_rng)).collect();
        let test_set =
            (0..test).map(|_| self.generate(width, &mut test_rng)).collect();
        (train_set, test_set)
    }
}

fn color(rng: &mut Rng) -> u8 {
    rng.range(1, NUM_COLORS) as u8
}

fn color_except(rng: &mut Rng, avoid: u8) -> u8 {
    loop {
        let c = color(rng);
        if c != avoid {
            return c;
        }
    }
}

// -------------------------------------------------------------- movement

fn gen_move(width: usize, shift: usize, rng: &mut Rng) -> Example {
    let len = rng.range(2, 6);
    let start = rng.range(0, width - len - shift);
    let c = color(rng);
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    for i in 0..len {
        input[start + i] = c;
        target[start + shift + i] = c;
    }
    Example { input, target }
}

/// Block slides right until it touches a marker pixel.
fn gen_move_dynamic(width: usize, rng: &mut Rng) -> Example {
    let len = rng.range(2, 5);
    let start = rng.range(0, width / 2 - len);
    let gap = rng.range(2, width - (start + len) - 1 - 1);
    let marker_pos = start + len + gap;
    let c = color(rng);
    let mc = color_except(rng, c);
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    for i in 0..len {
        input[start + i] = c;
        target[marker_pos - len + i] = c; // flush against the marker
    }
    input[marker_pos] = mc;
    target[marker_pos] = mc;
    Example { input, target }
}

/// Block moves 2 cells toward a marker (either side).
fn gen_move_towards(width: usize, rng: &mut Rng) -> Example {
    let len = rng.range(2, 5);
    let c = color(rng);
    let mc = color_except(rng, c);
    let marker_right = rng.bool();
    // Marker within a short range of the block (the original 1D-ARC rows
    // are narrow; the direction cue is local-ish).
    let (start, marker_pos) = if marker_right {
        let start = rng.range(1, (width - len - 9).max(2));
        let marker = (start + len + rng.range(3, 9)).min(width - 1);
        (start, marker)
    } else {
        let marker = rng.range(0, (width - len - 12).max(1));
        let start = marker + rng.range(3, 9);
        (start, marker)
    };
    let shift: isize = if marker_right { 2 } else { -2 };
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    for i in 0..len {
        input[start + i] = c;
        target[(start as isize + shift) as usize + i] = c;
    }
    input[marker_pos] = mc;
    target[marker_pos] = mc;
    Example { input, target }
}

// -------------------------------------------------------------- fill family

fn gen_fill(width: usize, rng: &mut Rng) -> Example {
    let len = rng.range(4, 9);
    let start = rng.range(0, width - len);
    let c = color(rng);
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    input[start] = c;
    input[start + len - 1] = c;
    for i in 0..len {
        target[start + i] = c;
    }
    Example { input, target }
}

/// Two hollow segments; only the *inside* of each is filled.
fn gen_padded_fill(width: usize, rng: &mut Rng) -> Example {
    let c = color(rng);
    let len1 = rng.range(3, 6);
    let len2 = rng.range(3, 6);
    let start1 = rng.range(0, width / 2 - len1);
    let start2 = rng.range(width / 2 + 1, width - len2);
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    for (start, len) in [(start1, len1), (start2, len2)] {
        input[start] = c;
        input[start + len - 1] = c;
        for i in 1..len - 1 {
            target[start + i] = c; // interior only: endpoints stay hollow
        }
    }
    Example { input, target }
}

fn gen_hollow(width: usize, rng: &mut Rng) -> Example {
    let len = rng.range(4, 9);
    let start = rng.range(0, width - len);
    let c = color(rng);
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    for i in 0..len {
        input[start + i] = c;
    }
    target[start] = c;
    target[start + len - 1] = c;
    Example { input, target }
}

// -------------------------------------------------------------- symmetry

/// A two-color block (head of one color, body of another) reverses in place.
fn gen_flip(width: usize, rng: &mut Rng) -> Example {
    let len = rng.range(3, 7);
    let start = rng.range(0, width - len);
    let head = color(rng);
    let body = color_except(rng, head);
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    input[start] = head;
    target[start + len - 1] = head;
    for i in 1..len {
        input[start + i] = body;
        target[start + i - 1] = body;
    }
    Example { input, target }
}

/// The whole row is mirrored around a fixed pivot marker.
fn gen_mirror(width: usize, rng: &mut Rng) -> Example {
    let pivot = width / 2;
    let mc = 5u8;
    let len = rng.range(2, 5);
    let side_left = rng.bool();
    let c = color_except(rng, mc);
    let offset = rng.range(2, pivot - len);
    let start = if side_left { pivot - offset - len } else { pivot + offset };
    let mut input = vec![0u8; width];
    input[pivot] = mc;
    for i in 0..len {
        input[start + i] = c;
    }
    let mut target = vec![0u8; width];
    target[pivot] = mc;
    for (x, &v) in input.iter().enumerate() {
        if v != 0 && x != pivot {
            let mirrored = (2 * pivot) as isize - x as isize;
            if mirrored >= 0 && (mirrored as usize) < width {
                target[mirrored as usize] = v;
            }
        }
    }
    Example { input, target }
}

// -------------------------------------------------------------- denoise

fn gen_denoise(width: usize, multicolor: bool, rng: &mut Rng) -> Example {
    let len = rng.range(4, 8);
    let start = rng.range(2, width - len - 2);
    let c = color(rng);
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    for i in 0..len {
        input[start + i] = c;
        target[start + i] = c;
    }
    // Scatter isolated noise pixels away from the block.
    let noise_n = rng.range(2, 5);
    let mut placed = 0;
    let mut guard = 0;
    while placed < noise_n && guard < 100 {
        guard += 1;
        let pos = rng.range(0, width);
        let clear = input[pos] == 0
            && (pos == 0 || input[pos - 1] == 0)
            && (pos + 1 >= width || input[pos + 1] == 0);
        // keep noise detached from the block so "isolated pixel" stays true
        if clear && (pos + 1 < start || pos > start + len) {
            input[pos] = if multicolor { color_except(rng, c) } else { c };
            placed += 1;
        }
    }
    Example { input, target }
}

// -------------------------------------------------------------- patterns

fn gen_pattern_copy(width: usize, multicolor: bool, rng: &mut Rng) -> Example {
    let len = rng.range(3, 6);
    let c = color_except(rng, 5); // 5 is reserved for the marker
    let pattern: Vec<u8> = (0..len)
        .map(|_| if multicolor { color_except(rng, 5) } else { c })
        .collect();
    // The original 1D-ARC rows are ~10-20 px with the destination marker a
    // short gap after the pattern; keep that geometry (gap 2..6) rather
    // than scattering the marker across the row. Clamp for narrow rows.
    let len = len.min(width.saturating_sub(8) / 2).max(2);
    let gap = rng.range(2, 7);
    let start = rng.range(0, (width - 2 * len - gap).max(1));
    let dst = start + len + gap;
    let marker = 5u8;
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    for i in 0..len {
        input[start + i] = pattern[i];
        target[start + i] = pattern[i];
        target[dst + i] = pattern[i];
    }
    input[dst] = marker; // destination marker
    Example { input, target }
}

// -------------------------------------------------------------- recolor

/// Blocks recolored by length parity: odd -> color 1, even -> color 2.
fn gen_recolor_odd_even(width: usize, rng: &mut Rng) -> Example {
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    let mut x = rng.range(0, 3);
    let c = color(rng);
    while x + 4 < width {
        let len = rng.range(1, 5);
        if x + len >= width {
            break;
        }
        for i in 0..len {
            input[x + i] = c;
            target[x + i] = if len % 2 == 1 { 1 } else { 2 };
        }
        x += len + rng.range(2, 5);
    }
    Example { input, target }
}

/// Blocks recolored by absolute size: 1 -> color 1, 2 -> 2, ..., 4 -> 4.
fn gen_recolor_size(width: usize, rng: &mut Rng) -> Example {
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    let mut x = rng.range(0, 3);
    let c = color(rng);
    while x + 5 < width {
        let len = rng.range(1, 5);
        if x + len >= width {
            break;
        }
        for i in 0..len {
            input[x + i] = c;
            target[x + i] = len as u8;
        }
        x += len + rng.range(2, 5);
    }
    Example { input, target }
}

/// Exactly two blocks; the longer one -> color 3, the shorter -> color 6.
fn gen_recolor_size_cmp(width: usize, rng: &mut Rng) -> Example {
    let len_a = rng.range(2, 7);
    let len_b = loop {
        let l = rng.range(2, 7);
        if l != len_a {
            break l;
        }
    };
    let c = color(rng);
    let start_a = rng.range(0, width / 2 - len_a);
    let start_b = rng.range(width / 2 + 1, width - len_b);
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    for i in 0..len_a {
        input[start_a + i] = c;
        target[start_a + i] = if len_a > len_b { 3 } else { 6 };
    }
    for i in 0..len_b {
        input[start_b + i] = c;
        target[start_b + i] = if len_b > len_a { 3 } else { 6 };
    }
    Example { input, target }
}

/// Block length doubles, anchored at its left edge.
fn gen_scaling(width: usize, rng: &mut Rng) -> Example {
    let len = rng.range(2, 6);
    let start = rng.range(0, width - 2 * len);
    let c = color(rng);
    let mut input = vec![0u8; width];
    let mut target = vec![0u8; width];
    for i in 0..len {
        input[start + i] = c;
    }
    for i in 0..2 * len {
        target[start + i] = c;
    }
    Example { input, target }
}

// -------------------------------------------------------------- encoding

/// One-hot encode a batch of rows into the artifact layout [B, W, 10].
pub fn one_hot_batch(rows: &[&[u8]], width: usize) -> Tensor {
    let b = rows.len();
    let mut t = Tensor::zeros(&[b, width, NUM_COLORS]);
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.len(), width);
        for (x, &c) in row.iter().enumerate() {
            t.set(&[i, x, c as usize], 1.0);
        }
    }
    t
}

/// Decode per-cell color logits [B, W, 10] back to color rows by argmax.
pub fn argmax_colors(logits: &Tensor) -> Vec<Vec<u8>> {
    let (b, w, nc) =
        (logits.shape()[0], logits.shape()[1], logits.shape()[2]);
    (0..b)
        .map(|i| {
            (0..w)
                .map(|x| {
                    let mut best = 0usize;
                    let mut best_v = f32::NEG_INFINITY;
                    for c in 0..nc {
                        let v = logits.at(&[i, x, c]);
                        if v > best_v {
                            best_v = v;
                            best = c;
                        }
                    }
                    best as u8
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blocks(row: &[u8]) -> Vec<(usize, usize, u8)> {
        // (start, len, color) of maximal non-zero runs
        let mut out = vec![];
        let mut i = 0;
        while i < row.len() {
            if row[i] != 0 {
                let c = row[i];
                let start = i;
                while i < row.len() && row[i] == c {
                    i += 1;
                }
                out.push((start, i - start, c));
            } else {
                i += 1;
            }
        }
        out
    }

    #[test]
    fn all_tasks_generate_valid_examples() {
        let mut rng = Rng::new(1);
        for task in Task::ALL {
            for _ in 0..50 {
                let ex = task.generate(32, &mut rng);
                assert_eq!(ex.input.len(), 32, "{}", task.name());
                assert_eq!(ex.target.len(), 32, "{}", task.name());
                assert!(ex.input.iter().any(|&c| c != 0), "{}", task.name());
                assert!(
                    ex.input.iter().all(|&c| (c as usize) < NUM_COLORS),
                    "{}", task.name()
                );
                assert!(
                    ex.target.iter().all(|&c| (c as usize) < NUM_COLORS),
                    "{}", task.name()
                );
            }
        }
    }

    #[test]
    fn move_tasks_shift_exactly() {
        let mut rng = Rng::new(2);
        for (task, shift) in [(Task::Move1, 1usize), (Task::Move2, 2),
                              (Task::Move3, 3)] {
            for _ in 0..30 {
                let ex = task.generate(32, &mut rng);
                let mut shifted = vec![0u8; 32];
                for (i, &c) in ex.input.iter().enumerate() {
                    if c != 0 {
                        shifted[i + shift] = c;
                    }
                }
                assert_eq!(shifted, ex.target, "{}", task.name());
            }
        }
    }

    #[test]
    fn fill_produces_solid_block() {
        let mut rng = Rng::new(3);
        for _ in 0..30 {
            let ex = Task::Fill.generate(32, &mut rng);
            let ib = blocks(&ex.input);
            let tb = blocks(&ex.target);
            assert_eq!(ib.len(), 2); // two endpoints
            assert_eq!(tb.len(), 1); // one solid block
            let (start, len, c) = tb[0];
            assert_eq!(ib[0].0, start);
            assert_eq!(ib[1].0 + ib[1].1, start + len);
            assert_eq!(ib[0].2, c);
        }
    }

    #[test]
    fn hollow_keeps_only_endpoints() {
        let mut rng = Rng::new(4);
        for _ in 0..30 {
            let ex = Task::Hollow.generate(32, &mut rng);
            let ib = blocks(&ex.input);
            assert_eq!(ib.len(), 1);
            let (start, len, c) = ib[0];
            let live: Vec<usize> = ex
                .target
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0)
                .map(|(i, _)| i)
                .collect();
            assert_eq!(live, vec![start, start + len - 1]);
            assert_eq!(ex.target[start], c);
        }
    }

    #[test]
    fn denoise_removes_isolated_pixels() {
        let mut rng = Rng::new(5);
        for multicolor in [false, true] {
            let task = if multicolor { Task::DenoiseMulticolor }
                       else { Task::Denoise };
            for _ in 0..30 {
                let ex = task.generate(32, &mut rng);
                let tb = blocks(&ex.target);
                assert_eq!(tb.len(), 1, "target must be just the block");
                assert!(tb[0].1 >= 4);
                // The block survives unchanged.
                let (start, len, c) = tb[0];
                for i in 0..len {
                    assert_eq!(ex.input[start + i], c);
                }
                // Input must actually contain noise.
                let in_blocks = blocks(&ex.input);
                assert!(in_blocks.len() > 1, "no noise generated");
            }
        }
    }

    #[test]
    fn mirror_is_involution_about_pivot() {
        let mut rng = Rng::new(6);
        for _ in 0..30 {
            let ex = Task::Mirror.generate(33, &mut rng);
            let pivot = 16usize;
            assert_eq!(ex.input[pivot], ex.target[pivot]);
            for x in 0..33usize {
                if x == pivot {
                    continue;
                }
                let m = 2 * pivot as isize - x as isize;
                if m >= 0 && (m as usize) < 33 {
                    assert_eq!(ex.input[x], ex.target[m as usize]);
                }
            }
        }
    }

    #[test]
    fn flip_reverses_block() {
        let mut rng = Rng::new(7);
        for _ in 0..30 {
            let ex = Task::Flip.generate(32, &mut rng);
            let ib = blocks_span(&ex.input);
            let tb = blocks_span(&ex.target);
            assert_eq!(ib, tb, "span must not move");
            let (s, e) = ib;
            let rev: Vec<u8> = ex.input[s..e].iter().rev().copied().collect();
            assert_eq!(&ex.target[s..e], &rev[..]);
        }
    }

    fn blocks_span(row: &[u8]) -> (usize, usize) {
        let first = row.iter().position(|&c| c != 0).unwrap();
        let last = row.iter().rposition(|&c| c != 0).unwrap();
        (first, last + 1)
    }

    #[test]
    fn pattern_copy_duplicates_pattern() {
        let mut rng = Rng::new(8);
        for multicolor in [false, true] {
            let task = if multicolor { Task::PatternCopyMulticolor }
                       else { Task::PatternCopy };
            for _ in 0..30 {
                let ex = task.generate(32, &mut rng);
                // Target contains the input pattern twice.
                let tb = blocks(&ex.target);
                assert!(tb.len() >= 2 || multicolor,
                        "expected two copies: {:?}", ex.target);
            }
        }
    }

    #[test]
    fn recolor_size_cmp_two_blocks_distinct_colors() {
        let mut rng = Rng::new(9);
        for _ in 0..30 {
            let ex = Task::RecolorSizeCmp.generate(32, &mut rng);
            let ib = blocks(&ex.input);
            let tb = blocks(&ex.target);
            assert_eq!(ib.len(), 2);
            assert_eq!(tb.len(), 2);
            // Same geometry.
            assert_eq!((ib[0].0, ib[0].1), (tb[0].0, tb[0].1));
            assert_eq!((ib[1].0, ib[1].1), (tb[1].0, tb[1].1));
            // Longer -> 3, shorter -> 6.
            let (long, short) = if ib[0].1 > ib[1].1 { (0, 1) } else { (1, 0) };
            assert_eq!(tb[long].2, 3);
            assert_eq!(tb[short].2, 6);
        }
    }

    #[test]
    fn recolor_odd_even_parity() {
        let mut rng = Rng::new(10);
        for _ in 0..30 {
            let ex = Task::RecolorOddEven.generate(32, &mut rng);
            let ib = blocks(&ex.input);
            let tb = blocks(&ex.target);
            assert_eq!(ib.len(), tb.len());
            for (i, t) in ib.iter().zip(&tb) {
                assert_eq!(t.2, if i.1 % 2 == 1 { 1 } else { 2 });
            }
        }
    }

    #[test]
    fn scaling_doubles_length() {
        let mut rng = Rng::new(11);
        for _ in 0..30 {
            let ex = Task::Scaling.generate(32, &mut rng);
            let ib = blocks(&ex.input);
            let tb = blocks(&ex.target);
            assert_eq!(ib.len(), 1);
            assert_eq!(tb.len(), 1);
            assert_eq!(tb[0].1, 2 * ib[0].1);
            assert_eq!(tb[0].0, ib[0].0);
            assert_eq!(tb[0].2, ib[0].2);
        }
    }

    #[test]
    fn find_accepts_labels_and_slugs() {
        assert_eq!(Task::find("Move 1"), Some(Task::Move1));
        assert_eq!(Task::find("move-1"), Some(Task::Move1));
        assert_eq!(Task::find("MOVE-1"), Some(Task::Move1));
        assert_eq!(Task::find("recolor-by-size"), Some(Task::RecolorSize));
        assert_eq!(Task::find("no-such-task"), None);
        for t in Task::ALL {
            assert_eq!(Task::find(&t.slug()), Some(t), "{}", t.name());
            assert_eq!(Task::find(t.name()), Some(t), "{}", t.name());
        }
    }

    #[test]
    fn datasets_deterministic_and_disjoint() {
        let (tr1, te1) = Task::Move2.dataset(32, 10, 10, 42);
        let (tr2, te2) = Task::Move2.dataset(32, 10, 10, 42);
        assert_eq!(tr1, tr2);
        assert_eq!(te1, te2);
        let (tr3, _) = Task::Move2.dataset(32, 10, 10, 43);
        assert_ne!(tr1, tr3);
        // Train and test streams differ.
        assert_ne!(tr1, te1);
    }

    #[test]
    fn one_hot_roundtrip() {
        let rows: Vec<Vec<u8>> = vec![vec![0, 3, 3, 0, 7], vec![1, 0, 0, 9, 0]];
        let refs: Vec<&[u8]> = rows.iter().map(|r| r.as_slice()).collect();
        let t = one_hot_batch(&refs, 5);
        assert_eq!(t.shape(), &[2, 5, 10]);
        let decoded = argmax_colors(&t);
        assert_eq!(decoded, rows);
    }

    #[test]
    fn gpt4_total_matches_paper() {
        let total: f64 = Task::ALL.iter().map(|t| t.gpt4_accuracy()).sum();
        assert!((total / 18.0 - 41.56).abs() < 0.5,
                "GPT-4 mean {}", total / 18.0);
        let nca: f64 = Task::ALL.iter().map(|t| t.paper_nca_accuracy()).sum();
        assert!((nca / 18.0 - 60.12).abs() < 0.5, "NCA mean {}", nca / 18.0);
    }
}
