//! Synthetic MNIST-role digit corpus (rust/README.md; paper-data substitution).
//!
//! No network access, so we synthesize a labelled 10-class digit-shaped
//! corpus: a 5x7 glyph font rendered into H x W with random scale, offset,
//! stroke dilation and pixel noise. The self-classifying / auto-encoding
//! NCAs only need visually-varied digit shapes with labels; class-boundary
//! topology (loops in 0/6/8/9, strokes elsewhere) is preserved.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// 5x7 bitmap font, row-major, one string per digit.
const GLYPHS: [[&str; 7]; 10] = [
    [
        "01110", "10001", "10011", "10101", "11001", "10001", "01110",
    ], // 0
    [
        "00100", "01100", "00100", "00100", "00100", "00100", "01110",
    ], // 1
    [
        "01110", "10001", "00001", "00110", "01000", "10000", "11111",
    ], // 2
    [
        "11110", "00001", "00001", "01110", "00001", "00001", "11110",
    ], // 3
    [
        "00010", "00110", "01010", "10010", "11111", "00010", "00010",
    ], // 4
    [
        "11111", "10000", "11110", "00001", "00001", "10001", "01110",
    ], // 5
    [
        "00110", "01000", "10000", "11110", "10001", "10001", "01110",
    ], // 6
    [
        "11111", "00001", "00010", "00100", "01000", "01000", "01000",
    ], // 7
    [
        "01110", "10001", "10001", "01110", "10001", "10001", "01110",
    ], // 8
    [
        "01110", "10001", "10001", "01111", "00001", "00010", "01100",
    ], // 9
];

/// One labelled digit image.
#[derive(Clone, Debug)]
pub struct Digit {
    /// f32[H, W] intensities in [0, 1].
    pub image: Tensor,
    pub label: u8,
}

/// Generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct MnistConfig {
    pub height: usize,
    pub width: usize,
    /// Max random translation (cells) applied to the glyph.
    pub max_shift: usize,
    /// Probability of stroke dilation (thicker digits).
    pub dilate_prob: f32,
    /// Per-pixel noise amplitude.
    pub noise: f32,
}

impl MnistConfig {
    pub fn for_grid(height: usize, width: usize) -> MnistConfig {
        MnistConfig {
            height,
            width,
            max_shift: (height.min(width) / 8).max(1),
            dilate_prob: 0.4,
            noise: 0.05,
        }
    }
}

/// Render one digit with random augmentations.
pub fn render_digit(label: u8, cfg: &MnistConfig, rng: &mut Rng) -> Digit {
    assert!(label < 10);
    assert!(cfg.height >= 8 && cfg.width >= 8, "grid too small for glyphs");
    let glyph = &GLYPHS[label as usize];

    // Base scale: fill ~70% of the grid.
    let scale_y = (cfg.height as f32 * 0.75) / 7.0;
    let scale_x = (cfg.width as f32 * 0.75) / 5.0;
    let scale = scale_y.min(scale_x) * (0.85 + 0.3 * rng.next_f32());
    let gh = (7.0 * scale).round() as usize;
    let gw = (5.0 * scale).round() as usize;
    let gh = gh.clamp(6, cfg.height);
    let gw = gw.clamp(4, cfg.width);

    let max_dy = (cfg.height - gh).min(cfg.max_shift * 2);
    let max_dx = (cfg.width - gw).min(cfg.max_shift * 2);
    let y0 = (cfg.height - gh) / 2
        + if max_dy > 0 { rng.range(0, max_dy + 1) } else { 0 }
        - max_dy / 2;
    let x0 = (cfg.width - gw) / 2
        + if max_dx > 0 { rng.range(0, max_dx + 1) } else { 0 }
        - max_dx / 2;

    let mut img = Tensor::zeros(&[cfg.height, cfg.width]);
    for gy in 0..gh {
        for gx in 0..gw {
            let sy = (gy * 7) / gh;
            let sx = (gx * 5) / gw;
            if glyph[sy].as_bytes()[sx] == b'1' {
                img.set(&[y0 + gy, x0 + gx], 1.0);
            }
        }
    }

    // Optional stroke dilation.
    if rng.bernoulli(cfg.dilate_prob) {
        let src = img.clone();
        for y in 0..cfg.height {
            for x in 0..cfg.width.saturating_sub(1) {
                if src.at(&[y, x]) > 0.5 {
                    img.set(&[y, x + 1], 1.0);
                }
            }
        }
    }

    // Intensity jitter + noise on ink pixels only (background stays 0 so
    // alive-masking still works).
    for y in 0..cfg.height {
        for x in 0..cfg.width {
            let v = img.at(&[y, x]);
            if v > 0.0 {
                let jitter = 1.0 - cfg.noise * rng.next_f32();
                img.set(&[y, x], (v * jitter).clamp(0.2, 1.0));
            }
        }
    }

    Digit { image: img, label }
}

/// A deterministic labelled dataset.
pub fn dataset(n: usize, cfg: &MnistConfig, seed: u64) -> Vec<Digit> {
    let mut rng = Rng::new(seed).fold_in(0xD161);
    (0..n)
        .map(|i| render_digit((i % 10) as u8, cfg, &mut rng))
        .collect()
}

/// Pack digit images into the artifact layout [B, H, W].
pub fn batch_images(digits: &[&Digit]) -> Tensor {
    let parts: Vec<Tensor> =
        digits.iter().map(|d| d.image.clone()).collect();
    Tensor::stack(&parts).expect("batch_images: inconsistent shapes")
}

/// One-hot labels [B, 10].
pub fn batch_labels(digits: &[&Digit]) -> Tensor {
    let mut t = Tensor::zeros(&[digits.len(), 10]);
    for (i, d) in digits.iter().enumerate() {
        t.set(&[i, d.label as usize], 1.0);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_digits() {
        let cfg = MnistConfig::for_grid(16, 16);
        let mut rng = Rng::new(1);
        for label in 0..10u8 {
            let d = render_digit(label, &cfg, &mut rng);
            assert_eq!(d.image.shape(), &[16, 16]);
            assert_eq!(d.label, label);
            let ink: usize =
                d.image.data().iter().filter(|&&v| v > 0.0).count();
            assert!(ink >= 10, "digit {label} too sparse: {ink}");
            assert!(ink < 200, "digit {label} too dense: {ink}");
        }
    }

    #[test]
    fn intensities_in_range() {
        let cfg = MnistConfig::for_grid(20, 20);
        let mut rng = Rng::new(2);
        for label in 0..10u8 {
            let d = render_digit(label, &cfg, &mut rng);
            for &v in d.image.data() {
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = MnistConfig::for_grid(16, 16);
        let a = dataset(20, &cfg, 7);
        let b = dataset(20, &cfg, 7);
        for (x, y) in a.iter().zip(&b) {
            assert!(x.image.bit_eq(&y.image));
            assert_eq!(x.label, y.label);
        }
        let c = dataset(20, &cfg, 8);
        assert!(a.iter().zip(&c).any(|(x, y)| !x.image.bit_eq(&y.image)));
    }

    #[test]
    fn labels_cycle() {
        let cfg = MnistConfig::for_grid(16, 16);
        let d = dataset(25, &cfg, 3);
        for (i, digit) in d.iter().enumerate() {
            assert_eq!(digit.label as usize, i % 10);
        }
    }

    #[test]
    fn augmentation_varies_images() {
        let cfg = MnistConfig::for_grid(16, 16);
        let mut rng = Rng::new(4);
        let a = render_digit(3, &cfg, &mut rng);
        let b = render_digit(3, &cfg, &mut rng);
        assert!(!a.image.bit_eq(&b.image), "augmentation had no effect");
    }

    #[test]
    fn batching_layouts() {
        let cfg = MnistConfig::for_grid(12, 12);
        let ds = dataset(4, &cfg, 5);
        let refs: Vec<&Digit> = ds.iter().collect();
        let imgs = batch_images(&refs);
        let labels = batch_labels(&refs);
        assert_eq!(imgs.shape(), &[4, 12, 12]);
        assert_eq!(labels.shape(), &[4, 10]);
        for i in 0..4 {
            assert_eq!(labels.at(&[i, i]), 1.0); // labels cycle 0,1,2,3
            let row_sum: f32 =
                (0..10).map(|c| labels.at(&[i, c])).sum();
            assert_eq!(row_sum, 1.0);
        }
    }
}
