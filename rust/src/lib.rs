//! # CAX-RS — Cellular Automata Accelerated
//!
//! A production-grade reproduction of *CAX: Cellular Automata Accelerated
//! in JAX* (Faldor & Cully, ICLR 2025) as a Rust framework with pluggable
//! execution backends: a pure-Rust [`backend::NativeBackend`] (bit-packed
//! SWAR kernels for the discrete CAs, cache-tiled f32 kernels for the
//! continuous/neural paths, batch-parallel worker pool) that runs
//! everywhere, and a PJRT engine (`pjrt` feature) that executes
//! AOT-lowered HLO artifacts from the JAX/Pallas layers — plus every
//! substrate the paper's evaluation needs (naive baselines, datasets,
//! sample pool, visualization, metrics, config, CLI).
//!
//! See `rust/README.md` for the architecture (layer diagram, backend
//! feature matrix, how to enable `pjrt`) and the experiment index.

// Tight index loops are the house style of the numeric kernels here;
// iterator rewrites of 3-D stencils obscure the math they implement.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod automata;
pub mod backend;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod metrics;
pub mod pool;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod viz;

pub use backend::{
    Backend, CaProgram, NativeBackend, NativeTrainBackend, ProgramBackend,
    Value,
};
pub use tensor::Tensor;
