//! # CAX-RS — Cellular Automata Accelerated
//!
//! A production-grade reproduction of *CAX: Cellular Automata Accelerated
//! in JAX* (Faldor & Cully, ICLR 2025) as a three-layer Rust + JAX + Pallas
//! stack: Pallas kernels (L1) and JAX models (L2) are AOT-lowered to HLO
//! text at build time; this crate (L3) is the deployable framework that
//! loads, schedules, trains and benchmarks them via PJRT — plus every
//! substrate the paper's evaluation needs (naive baselines, datasets,
//! sample pool, visualization, metrics, config, CLI).
//!
//! See DESIGN.md for the architecture and experiment index, EXPERIMENTS.md
//! for paper-vs-measured results.

pub mod automata;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod metrics;
pub mod pool;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod viz;

pub use tensor::Tensor;
