//! # CAX-RS — Cellular Automata Accelerated
//!
//! A production-grade reproduction of *CAX: Cellular Automata Accelerated
//! in JAX* (Faldor & Cully, ICLR 2025) as a Rust framework with pluggable
//! execution backends: a pure-Rust [`backend::NativeBackend`] (bit-packed
//! SWAR kernels for the discrete CAs, cache-tiled f32 kernels for the
//! continuous/neural paths, batch-parallel worker pool) that runs
//! everywhere, and a PJRT engine (`pjrt` feature) that executes
//! AOT-lowered HLO artifacts from the JAX/Pallas layers — plus every
//! substrate the paper's evaluation needs (naive baselines, datasets,
//! sample pool, visualization, metrics, config, CLI).
//!
//! ## The two execution contracts
//!
//! - [`backend::Backend`] runs *classic-CA programs*
//!   ([`backend::CaProgram`]: ECA, Life, Lenia — size-adaptive between
//!   sparse-tap and in-tree spectral FFT kernels, including
//!   multi-channel / multi-kernel worlds — and the NCA forward cell)
//!   on batched states — see the runnable example on
//!   [`backend::NativeBackend`].
//! - [`backend::ProgramBackend`] runs *named, manifest-described
//!   programs* — the training and evaluation surface. The default build
//!   trains the paper's growing-NCA (App. B), self-classifying-MNIST
//!   and 1D-ARC (§5.3) experiments end to end through
//!   [`backend::NativeTrainBackend`] (hand-rolled BPTT + Adam,
//!   gradient-checked against finite differences); `pjrt` builds swap
//!   in fused XLA train steps with zero coordinator changes. The named
//!   program catalogue and its calling convention live on the
//!   [`backend::ProgramBackend`] docs.
//!
//! Above both contracts sits [`serve`]: a std-only multi-session
//! simulation service (`cax serve`) that keeps each session's board
//! backend-*resident* ([`backend::Resident`]) and coalesces pending
//! step requests into one batched launch per shape class per tick —
//! bitwise identical to stepping each session alone, measured >= 5x
//! faster in aggregate by `benches/serve_load.rs`.
//!
//! Everything reports through [`obs`]: lock-free latency histograms,
//! RAII kernel spans, Prometheus `/metrics` exposition and
//! Chrome/Perfetto `--trace` capture — observation that never
//! perturbs a trajectory (see the [`obs`] contract).
//!
//! Entry points: the `cax` CLI (`sim`, `train`, `eval`, `serve`), the
//! `examples/` directory (`native_rollout`, `native_train`, `arc_1d`,
//! `quickstart`, `train_growing_nca`), and the
//! [`coordinator::experiments`] drivers the integration tests and
//! benches share.
//!
//! See `rust/README.md` for the architecture (layer diagram, backend
//! feature matrix, how to enable `pjrt`) and the experiment index.

// Tight index loops are the house style of the numeric kernels here;
// iterator rewrites of 3-D stencils obscure the math they implement.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod automata;
pub mod backend;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod metrics;
pub mod obs;
pub mod pool;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
pub mod viz;

pub use backend::{
    Backend, CaProgram, NativeBackend, NativeTrainBackend, ProgramBackend,
    Value,
};
pub use tensor::Tensor;
