//! `cax` — the CAX-RS launcher.
//!
//! Subcommands:
//!   list                         print the Table-1 CA registry + status
//!   info <artifact>              manifest signature of one artifact
//!   backends                     execution backends in this build
//!   check                        compile every registry artifact [pjrt]
//!   sim <eca|life|lenia> ...     run a classic CA on any backend path
//!   train <ca> ...               train a neural CA end to end (native:
//!                                growing, mnist, arc; all keys [pjrt])
//!   eval <arc|mnist|autoenc3d>   evaluate a trained neural CA (native:
//!                                arc; the rest need [pjrt])
//!   serve ...                    multi-session simulation service with
//!                                a coalescing scheduler (HTTP/1.1)
//!   top ...                      live fleet dashboard: polls a serve
//!                                (router or worker) `/metrics.json`
//!   bench compare ...            regression gate over BENCH_*.json
//!                                reports (rows matched by label)
//!
//! Global flags: --artifacts DIR  --out DIR  --seed N  --config FILE
//!               --backend native|pjrt  --trace FILE
//!
//! `--trace FILE` captures kernel spans, scheduler ticks and batch
//! packing as Chrome/Perfetto trace-event JSON (open the file at
//! <https://ui.perfetto.dev>). `CAX_LOG=error|warn|info|debug` filters
//! the stderr logger.

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use cax::automata::lenia::{LeniaParams, LeniaWorld};
use cax::automata::WolframRule;
use cax::backend::{CaProgram, NativeBackend, NativeTrainBackend};
use cax::config::Config;
use cax::coordinator::evaluator;
use cax::coordinator::trainer::TrainCfg;
use cax::coordinator::{experiments, Path as SimPath, Simulator};
use cax::datasets::arc1d::Task;
use cax::obs::MetricSnapshot;
use cax::runtime::Manifest;
use cax::util::json::Json;
use cax::util::rng::Rng;
use cax::util::timer::Timer;
use cax::viz::spacetime;

#[cfg(feature = "pjrt")]
use cax::coordinator::registry;
#[cfg(feature = "pjrt")]
use cax::datasets::mnist::{self, MnistConfig};
#[cfg(feature = "pjrt")]
use cax::runtime::Engine;

fn usage() -> &'static str {
    "cax — Cellular Automata Accelerated (Rust coordinator)

USAGE:
    cax [--artifacts DIR] [--out DIR] [--seed N] [--config FILE]
        [--backend native|pjrt] [--trace FILE] <COMMAND>

    --trace FILE writes a Chrome/Perfetto trace (kernel spans,
    scheduler ticks, batch packing) — open it at ui.perfetto.dev.
    CAX_LOG=error|warn|info|debug filters the stderr logger (default
    info).

COMMANDS:
    list                      Table-1 registry and artifact status
    info <artifact>           print one artifact's manifest signature
    backends                  execution backends available in this build
    check                     compile every registry artifact      [pjrt]
    sim <eca|life|lenia>      run a classic CA
        [--path fused|stepwise|naive|native] [--steps N] [--rule R]
        [--batch B] [--width W] [--height H] [--render]
        lenia also takes [--radius R] [--size N] [--kernels K]; the
        native path prints the selected kernel (sparse-tap vs fft)
        and achieved cells/sec; K > 1 runs a multi-kernel spectral
        world
    train <ca-key>            train a neural CA end to end
        [--steps N]           --backend native: growing, mnist, arc
        [--backend native]    (hermetic, hand-rolled BPTT + Adam);
                              --backend pjrt: all keys via fused
                              artifacts                           [pjrt]
    eval <arc|mnist|autoenc3d> [--train-steps N] [--task NAME|all]
                              --backend native: arc (per-task
                              exact-match vs the paper's GPT-4 row;
                              --task all reproduces Table 2);
                              mnist/autoenc3d need                [pjrt]
    serve                     multi-session simulation service: sessions
        [--port P]            step through a coalescing scheduler (one
        [--threads T]         batched launch per shape class per tick);
        [--max-sessions N]    HTTP/1.1 on 127.0.0.1, JSON + PPM
        [--max-batch B]       snapshots; SIGTERM/ctrl-c drains and
        [--max-pending Q]     exits 0 (see rust/README.md for the curl
        [--max-steps S]       quickstart)
        [--tick-us U]
        [--state-dir DIR]     checkpoint/restore + LRU eviction: the
                              session cap becomes a working-set cap,
                              idle sessions park on disk and rehydrate
                              bit-identically on next touch; SSE frames
                              at GET /sessions/<id>/stream
        [--shards N]          fleet mode: fork N worker processes and
                              route sessions across them by id modulo N
                              (workers take --shard-index/--shard-count
                              internally; --state-dir shards as
                              DIR/shard-<i>); the router scrapes every
                              worker's /metrics.json and serves one
                              exact fleet-wide /metrics page; with
                              --trace FILE it merges worker captures
                              into one Perfetto file on drain
    top                       live terminal dashboard: polls a serve
        [--addr A]            /metrics.json (router or single worker;
        [--interval-ms MS]    default addr 127.0.0.1:7878, interval
        [--iterations N]      1000 ms) and redraws sessions, queue
                              depth now/high-water, exact p99 wait/step
                              and step-path counters per shard; N = 0
                              (the default) polls until interrupted
    bench compare             regression gate over BENCH_*.json reports
        --current FILE        rows matched by label on median_s; fails
        --baseline FILE       when current/baseline - 1 exceeds
        [--threshold R]       --threshold (default 0.25); --soft
        [--soft]              reports but never fails (the CI default)

The default build runs everything marked-free above hermetically on the
native backend (incl. `train growing|mnist|arc`, `eval arc` and
`serve`); [pjrt] commands need `--features pjrt` plus artifacts."
}

struct Cli {
    cfg: Config,
    args: Vec<String>,
    /// `--trace FILE`: arm a Perfetto trace capture for the whole
    /// command and write it here on exit.
    trace: Option<PathBuf>,
}

impl Cli {
    fn parse() -> Result<Cli> {
        let mut cfg = Config::default();
        let mut args = vec![];
        let mut trace = None;
        let mut it = std::env::args().skip(1);
        while let Some(a) = it.next() {
            match a.as_str() {
                "--artifacts" => {
                    cfg.artifacts_dir =
                        PathBuf::from(next(&mut it, "--artifacts")?)
                }
                "--out" => cfg.out_dir = PathBuf::from(next(&mut it, "--out")?),
                "--seed" => cfg.seed = next(&mut it, "--seed")?.parse()?,
                "--config" => {
                    let path = PathBuf::from(next(&mut it, "--config")?);
                    cfg = Config::from_file(&path)?;
                }
                "--trace" => {
                    trace = Some(PathBuf::from(next(&mut it, "--trace")?))
                }
                _ => args.push(a),
            }
        }
        Ok(Cli { cfg, args, trace })
    }

    /// Value of `--flag` within the subcommand args, if present.
    fn flag(&self, name: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == name)
            .and_then(|i| self.args.get(i + 1))
            .map(|s| s.as_str())
    }

    fn has(&self, name: &str) -> bool {
        self.args.iter().any(|a| a == name)
    }

    fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            Some(v) => v
                .parse()
                .with_context(|| format!("{name} wants an integer, got {v:?}")),
            None => Ok(default),
        }
    }
}

fn next(it: &mut impl Iterator<Item = String>, flag: &str) -> Result<String> {
    it.next().with_context(|| format!("{flag} needs a value"))
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<()> {
    let cli = Cli::parse()?;
    let Some(cmd) = cli.args.first().map(String::as_str) else {
        println!("{}", usage());
        return Ok(());
    };
    if cli.trace.is_some() {
        cax::obs::trace::start();
    }
    let result = match cmd {
        "list" => cmd_list(&cli),
        "info" => cmd_info(&cli),
        "backends" => cmd_backends(&cli),
        "check" => cmd_check(&cli),
        "sim" => cmd_sim(&cli),
        "train" => cmd_train(&cli),
        "eval" => cmd_eval(&cli),
        "serve" => cmd_serve(&cli),
        "top" => cmd_top(&cli),
        "bench" => cmd_bench(&cli),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{}", usage()),
    };
    if let Some(path) = &cli.trace {
        // Fleet runs already wrote the merged trace (the router takes
        // the capture in `write_merged`); only write when one is
        // still pending.
        if cax::obs::trace::pending() {
            match cax::obs::trace::write(path) {
                Ok(n) => println!(
                    "wrote {n} trace events to {} (open at \
                     ui.perfetto.dev)",
                    path.display()
                ),
                Err(e) => cax::log_warn!("trace: {e:#}"),
            }
        }
    }
    result
}

fn load_manifest(cli: &Cli) -> Result<Manifest> {
    let dir = cli.cfg.resolved_artifacts_dir();
    Manifest::load(&dir).with_context(|| {
        format!("loading artifacts from {} (run `make artifacts` first?)",
                dir.display())
    })
}

#[cfg(feature = "pjrt")]
fn engine(cli: &Cli) -> Result<Engine> {
    let dir = cli.cfg.resolved_artifacts_dir();
    Engine::load(&dir).with_context(|| {
        format!("loading artifacts from {} (run `make artifacts` first?)",
                dir.display())
    })
}

// ------------------------------------------------------------------ list

fn cmd_list(cli: &Cli) -> Result<()> {
    // Absent manifest -> native-only listing; present-but-broken
    // manifest is a real error the user needs to see.
    let dir = cli.cfg.resolved_artifacts_dir();
    let manifest = if dir.join("manifest.json").exists() {
        Some(load_manifest(cli)?)
    } else {
        None
    };
    let missing = manifest
        .as_ref()
        .map(|m| cax::coordinator::registry::missing_artifacts(m));
    println!("{:<12} {:<46} {:<11} {:<5} status", "KEY", "CELLULAR AUTOMATON",
             "TYPE", "DIMS");
    for e in cax::coordinator::registry::table1() {
        let status = match &missing {
            Some(miss) => {
                let prefix = format!("{}:", e.key);
                if miss.iter().any(|m| m.starts_with(&prefix)) {
                    "MISSING ARTIFACTS"
                } else {
                    "ready"
                }
            }
            None => {
                // No artifacts on disk: the classic rows still run on
                // the native backend, and the growing/mnist rows train
                // through the native BPTT train step.
                if matches!(e.key, "eca" | "life" | "lenia") {
                    "ready (native)"
                } else if matches!(e.key, "growing" | "mnist" | "arc") {
                    "trainable (native)"
                } else {
                    "needs artifacts"
                }
            }
        };
        println!(
            "{:<12} {:<46} {:<11} {:<5} {status}",
            e.key, e.label, e.ca_type.name(), e.dimensions,
        );
    }
    println!("\nartifacts: {}", cli.cfg.resolved_artifacts_dir().display());
    if let Some(miss) = missing {
        if !miss.is_empty() {
            println!("missing: {miss:?}");
        }
    }
    Ok(())
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let name = cli.args.get(1).context("info: which artifact?")?;
    let manifest = load_manifest(cli)?;
    let info = manifest.artifact(name)?;
    println!("artifact {name}");
    for s in &info.inputs {
        println!("  in  {:<10} {}{:?}", s.name, s.dtype.name(), s.shape);
    }
    for s in &info.outputs {
        println!("  out {:<10} {}{:?}", s.name, s.dtype.name(), s.shape);
    }
    Ok(())
}

fn cmd_backends(_cli: &Cli) -> Result<()> {
    let native = NativeBackend::new();
    println!("{:<8} {:<10} detail", "BACKEND", "STATUS");
    println!(
        "{:<8} {:<10} bit-packed SWAR (ECA/Life), tiled f32 (Lenia/NCA), \
         {} worker threads, simd {}, stepping {}",
        "native", "ready", native.threads(), native.simd_status(),
        native.activity_status()
    );
    #[cfg(feature = "pjrt")]
    println!("{:<8} {:<10} XLA artifacts via PJRT (needs `make artifacts`)",
             "pjrt", "compiled");
    #[cfg(not(feature = "pjrt"))]
    println!("{:<8} {:<10} rebuild with `--features pjrt` to enable",
             "pjrt", "off");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_check(cli: &Cli) -> Result<()> {
    let eng = engine(cli)?;
    let missing = registry::missing_artifacts(eng.manifest());
    if !missing.is_empty() {
        bail!("manifest incomplete: {missing:?}");
    }
    let mut names: Vec<String> =
        eng.manifest().artifacts.keys().cloned().collect();
    names.sort();
    for name in &names {
        let t = Timer::start();
        eng.ensure_compiled(name)
            .with_context(|| format!("compiling {name}"))?;
        println!("  compiled {name:<24} {:>8.1} ms", t.elapsed_ms());
    }
    println!("check: {}/{} artifacts compile on {}", names.len(),
             names.len(), eng.platform());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_check(_cli: &Cli) -> Result<()> {
    bail!("`cax check` compiles XLA artifacts; rebuild with --features pjrt")
}

// ------------------------------------------------------------------- sim

/// Default state shape for artifact-free runs, per CA.
fn local_shape(cli: &Cli, ca: &str) -> Result<Vec<usize>> {
    Ok(match ca {
        "eca" => vec![
            cli.flag_usize("--batch", 32)?,
            cli.flag_usize("--width", 1024)?,
        ],
        "life" => vec![
            cli.flag_usize("--batch", 8)?,
            cli.flag_usize("--height", 256)?,
            cli.flag_usize("--width", 256)?,
        ],
        other => bail!("unknown CA {other:?}"),
    })
}

fn cmd_sim(cli: &Cli) -> Result<()> {
    let ca = cli
        .args
        .get(1)
        .context("sim: which CA (eca|life|lenia)?")?
        .clone();
    let backend_flag = cli.flag("--backend");
    let default_path = match backend_flag {
        Some("native") => "native",
        Some("pjrt") => "fused",
        Some(other) => bail!("unknown --backend {other:?} (native|pjrt)"),
        None if cfg!(feature = "pjrt") => "fused",
        None => "native",
    };
    let path = SimPath::parse(cli.flag("--path").unwrap_or(default_path))?;

    if path.needs_programs() {
        #[cfg(feature = "pjrt")]
        return cmd_sim_xla(cli, &ca, path);
        #[cfg(not(feature = "pjrt"))]
        bail!(
            "--path {} needs the pjrt feature; this build runs \
             --path native|naive",
            path.name()
        );
    }
    cmd_sim_local(cli, &ca, path)
}

/// Native/naive Lenia with explicit geometry: `--radius`, `--size N`
/// (square board; `--height`/`--width` override per axis) and
/// `--kernels K` (K > 1 builds a multi-kernel spectral demo world).
/// Prints the selected kernel path and achieved cells/sec so bench
/// claims are reproducible straight from the CLI.
fn cmd_sim_lenia_local(cli: &Cli, path: SimPath) -> Result<()> {
    let sim = Simulator::native_only();
    let mut rng = Rng::new(cli.cfg.seed);
    let size = cli.flag_usize("--size", 128)?;
    let h = cli.flag_usize("--height", size)?;
    let w = cli.flag_usize("--width", size)?;
    let b = cli.flag_usize("--batch", 4)?;
    let steps = cli.flag_usize("--steps", 64)?;
    let radius =
        cli.flag_usize("--radius", LeniaParams::default().radius)?;
    let kernels = cli.flag_usize("--kernels", 1)?;
    let params = LeniaParams { radius, ..Default::default() };

    let kpath = if kernels > 1 {
        if path == SimPath::Native {
            "fft (multi-kernel world)".to_string()
        } else {
            "naive per-cell (multi-kernel world)".to_string()
        }
    } else if path == SimPath::Native {
        format!(
            "{} (crossover-selected)",
            Simulator::lenia_native_path(params, h, w)
        )
    } else {
        "naive per-cell".to_string()
    };

    let state;
    let out;
    let t;
    if kernels > 1 {
        let world = LeniaWorld::demo(kernels, radius);
        state = Simulator::random_binary_state(
            &[b, world.channels, h, w],
            &mut rng,
        );
        t = Timer::start();
        out = sim.run_lenia_world(path, &world, &state, steps)?;
    } else {
        state = Simulator::random_binary_state(&[b, h, w], &mut rng);
        t = Timer::start();
        out = sim.run_lenia_params(path, params, &state, steps)?;
    }
    let dt = t.elapsed_secs();
    let updates = state.numel() as f64 * steps as f64;
    println!(
        "lenia [{}] radius {radius}, {steps} steps on {:?}: {:.3}s  \
         ({})  kernel path: {kpath}  final mean {:.4}",
        path.name(), state.shape(), dt,
        cax::metrics::rate_str(updates, dt, "cells"), out.mean()
    );

    if cli.has("--render") {
        std::fs::create_dir_all(&cli.cfg.out_dir)?;
        // Batch element 0; channel 0 of a multi-kernel world.
        let field = if kernels > 1 {
            out.index_axis0(0).index_axis0(0)
        } else {
            out.index_axis0(0)
        };
        let img = spacetime::render_field(&field)?;
        let path_out = cli.cfg.out_dir.join("lenia.ppm");
        img.upscale(4).write_ppm(&path_out)?;
        println!("wrote {}", path_out.display());
    }
    Ok(())
}

/// Native/naive simulation — no artifacts, no XLA; shapes from flags.
fn cmd_sim_local(cli: &Cli, ca: &str, path: SimPath) -> Result<()> {
    if ca == "lenia" {
        return cmd_sim_lenia_local(cli, path);
    }
    let sim = Simulator::native_only();
    let mut rng = Rng::new(cli.cfg.seed);
    let shape = local_shape(cli, ca)?;
    let steps = cli.flag_usize("--steps", 256)?;
    let state = Simulator::random_binary_state(&shape, &mut rng);
    let rule = WolframRule::parse(cli.flag("--rule").unwrap_or("30"))?;

    let t = Timer::start();
    let out = match ca {
        "eca" => sim.run_eca(path, &state, rule, steps)?,
        "life" => sim.run_life(path, &state, steps)?,
        _ => unreachable!(),
    };
    let dt = t.elapsed_secs();
    let updates = state.numel() as f64 * steps as f64;
    // The unbatched board shape drives the cost model (mirrors the
    // Lenia `kernel path:` line — the executed path, not a guess).
    let prog = match ca {
        "eca" => CaProgram::Eca { rule },
        _ => CaProgram::Life,
    };
    let spath = if path == SimPath::Native {
        Simulator::native_step_path(&prog, &shape[1..], steps)
    } else {
        "dense (naive)"
    };
    println!(
        "{ca} [{}] {steps} steps on {:?}: {:.3}s  ({})  step path: \
         {spath}  final mean {:.4}",
        path.name(), shape, dt,
        cax::metrics::rate_str(updates, dt, "cell updates"), out.mean()
    );

    if cli.has("--render") {
        std::fs::create_dir_all(&cli.cfg.out_dir)?;
        let img = match ca {
            "eca" => {
                // Space-time diagram of batch element 0 via the naive sim
                // (rendering is not the hot path).
                let one = cax::Tensor::stack(&[state.index_axis0(0)])?;
                let mut esim =
                    cax::automata::EcaSim::from_tensor(rule, &one);
                let st = esim.spacetime(0, steps.min(512));
                spacetime::render_spacetime_1d(&st)?
            }
            _ => spacetime::render_field(&out.index_axis0(0))?,
        };
        let path_out = cli.cfg.out_dir.join(format!("{ca}.ppm"));
        img.upscale(4).write_ppm(&path_out)?;
        println!("wrote {}", path_out.display());
    }
    Ok(())
}

/// Fused/stepwise simulation over the PJRT engine (artifact shapes).
#[cfg(feature = "pjrt")]
fn cmd_sim_xla(cli: &Cli, ca: &str, path: SimPath) -> Result<()> {
    let eng = engine(cli)?;
    let sim = Simulator::new(&eng);
    let mut rng = Rng::new(cli.cfg.seed);

    let (artifact, default_steps) = match ca {
        "eca" => ("eca_rollout", 256),
        "life" => ("life_rollout", 256),
        "lenia" => ("lenia_rollout", 64),
        other => bail!("unknown CA {other:?}"),
    };
    let steps = match cli.flag("--steps") {
        Some(s) => s.parse::<usize>()?,
        None => eng
            .manifest()
            .artifact(artifact)
            .ok()
            .and_then(|i| i.meta_usize("steps"))
            .unwrap_or(default_steps),
    };

    let state = sim.random_state(artifact, &mut rng)?;
    let t = Timer::start();
    let out = match ca {
        "eca" => {
            let rule = WolframRule::parse(cli.flag("--rule").unwrap_or("30"))?;
            sim.run_eca(path, &state, rule, steps)?
        }
        "life" => sim.run_life(path, &state, steps)?,
        "lenia" => sim.run_lenia(path, &state, steps)?,
        _ => unreachable!(),
    };
    let dt = t.elapsed_secs();
    let updates = sim.cell_updates(artifact, steps)?;
    println!(
        "{ca} [{}] {} steps: {:.3}s  ({})  final mean {:.4}",
        path.name(), steps, dt,
        cax::metrics::rate_str(updates, dt, "cell updates"), out.mean()
    );

    if cli.has("--render") {
        std::fs::create_dir_all(&cli.cfg.out_dir)?;
        let img = match ca {
            "eca" => {
                let rule =
                    WolframRule::parse(cli.flag("--rule").unwrap_or("30"))?;
                let (_, traj) = sim.eca_traj(&state, rule)?;
                // traj [T, B, W]: render batch element 0 as [T, W].
                let (t_len, w) = (traj.shape()[0], traj.shape()[2]);
                let mut flat = cax::Tensor::zeros(&[t_len, w]);
                for ti in 0..t_len {
                    for x in 0..w {
                        flat.set(&[ti, x], traj.at(&[ti, 0, x]));
                    }
                }
                spacetime::render_spacetime_1d(&flat)?
            }
            _ => spacetime::render_field(&out.index_axis0(0))?,
        };
        let path_out = cli.cfg.out_dir.join(format!("{ca}.ppm"));
        img.upscale(4).write_ppm(&path_out)?;
        println!("wrote {}", path_out.display());
    }
    Ok(())
}

// ----------------------------------------------------------------- train

fn train_cfg(cli: &Cli) -> Result<TrainCfg> {
    let steps = match cli.flag("--steps") {
        Some(s) => s.parse::<usize>()?,
        None => cli.cfg.train.steps,
    };
    Ok(TrainCfg {
        steps,
        seed: cli.cfg.seed as u32,
        log_every: cli.cfg.train.log_every,
        out_dir: cli.cfg.train.write_outputs.then(|| cli.cfg.out_dir.clone()),
    })
}

fn print_train_summary(key: &str, run: &experiments::TrainRun, steps: usize,
                       secs: f64) {
    let (first, last) = run.history.window_means(10);
    println!(
        "{key}: {steps} steps in {secs:.1}s — loss first-window {first:.5} \
         -> last-window {last:.5}{}",
        if run.improved() { "" } else { "  (WARNING: no improvement)" },
    );
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let key = cli
        .args
        .get(1)
        .context("train: which CA key? (see `cax list`)")?
        .clone();
    let backend = cli
        .flag("--backend")
        .unwrap_or(if cfg!(feature = "pjrt") { "pjrt" } else { "native" });
    match backend {
        "native" => cmd_train_native(cli, &key),
        "pjrt" => cmd_train_pjrt(cli, &key),
        other => bail!("unknown --backend {other:?} (native|pjrt)"),
    }
}

/// Hand-rolled BPTT + Adam on the native backend — no artifacts, no XLA,
/// no Python anywhere.
fn cmd_train_native(cli: &Cli, key: &str) -> Result<()> {
    if !matches!(key, "growing" | "mnist" | "arc") {
        bail!(
            "the native backend trains `growing`, `mnist` and `arc`; \
             {key:?} needs the pjrt backend (rebuild with --features pjrt \
             and run `make artifacts`)"
        );
    }
    let backend = NativeTrainBackend::new();
    let cfg = train_cfg(cli)?;
    println!(
        "training {key} natively for {} steps (seed {}, {} worker \
         threads)...",
        cfg.steps, cfg.seed, backend.threads()
    );
    let t = Timer::start();
    let run =
        experiments::train_by_key(&backend, key, &cfg, cli.cfg.pool.size)?
            .expect("neural CA");
    print_train_summary(key, &run, cfg.steps, t.elapsed_secs());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_train_pjrt(cli: &Cli, key: &str) -> Result<()> {
    let entry = registry::find(key)
        .with_context(|| format!("no registry entry {key:?}"))?;
    if entry.params_blob.is_none() {
        bail!("{key} is a classic CA — use `cax sim {key}`");
    }
    let eng = engine(cli)?;
    let cfg = train_cfg(cli)?;
    println!("training {key} for {} steps (seed {})...", cfg.steps,
             cfg.seed);
    let t = Timer::start();
    let run = experiments::train_by_key(&eng, key, &cfg, cli.cfg.pool.size)?
        .expect("neural CA");
    print_train_summary(key, &run, cfg.steps, t.elapsed_secs());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_pjrt(_cli: &Cli, key: &str) -> Result<()> {
    bail!(
        "`cax train --backend pjrt` runs fused XLA train-step artifacts \
         and needs a --features pjrt build; this build trains natively: \
         `cax train {key} --backend native`"
    )
}

// ----------------------------------------------------------------- serve

/// The coalescing multi-session simulation service (`cax::serve`).
fn cmd_serve(cli: &Cli) -> Result<()> {
    let defaults = cax::serve::ServeConfig::default();
    let cfg = cax::serve::ServeConfig {
        port: match cli.flag("--port") {
            Some(p) => p
                .parse()
                .with_context(|| format!("--port wants a u16, got {p:?}"))?,
            None => defaults.port,
        },
        threads: cli.flag_usize("--threads", defaults.threads)?,
        max_sessions: cli
            .flag_usize("--max-sessions", defaults.max_sessions)?,
        max_batch: cli.flag_usize("--max-batch", defaults.max_batch)?,
        max_pending: cli.flag_usize("--max-pending", defaults.max_pending)?,
        max_steps: cli.flag_usize("--max-steps", defaults.max_steps)?,
        seed: cli.cfg.seed,
        tick_window: std::time::Duration::from_micros(
            cli.flag_usize("--tick-us",
                           defaults.tick_window.as_micros() as usize)?
                as u64,
        ),
        state_dir: cli.flag("--state-dir").map(PathBuf::from),
        shards: cli.flag_usize("--shards", defaults.shards)?,
        shard: match (cli.flag("--shard-index"), cli.flag("--shard-count"))
        {
            (Some(i), Some(n)) => {
                let index: u64 = i.parse().with_context(|| {
                    format!("--shard-index wants a u64, got {i:?}")
                })?;
                let count: u64 = n.parse().with_context(|| {
                    format!("--shard-count wants a u64, got {n:?}")
                })?;
                if count == 0 || index >= count {
                    bail!("--shard-index {index} out of range for \
                           --shard-count {count}");
                }
                Some((index, count))
            }
            (None, None) => None,
            _ => bail!(
                "--shard-index and --shard-count go together"
            ),
        },
    };
    if cfg.shards >= 2 {
        if cfg.shard.is_some() {
            bail!("--shards spawns workers itself; don't also pass \
                   --shard-index/--shard-count");
        }
        return cax::serve::router::run(&cfg, cli.trace.as_deref());
    }
    cax::serve::run(&cfg)
}

// ------------------------------------------------------------------- top

/// One-shot `GET` returning the parsed JSON body (`Connection:
/// close`, EOF-delimited — the same framing the shard router's
/// scraper uses).
fn http_get_json(addr: &str, path: &str) -> Result<Json> {
    use std::io::{Read as _, Write as _};
    let timeout = std::time::Duration::from_secs(5);
    let sock: std::net::SocketAddr = addr
        .parse()
        .with_context(|| format!("--addr wants HOST:PORT, got {addr:?}"))?;
    let mut stream = std::net::TcpStream::connect_timeout(&sock, timeout)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    write!(
        stream,
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
    )?;
    let mut text = String::new();
    stream.read_to_string(&mut text)?;
    let status = text.lines().next().unwrap_or("").to_string();
    let body =
        text.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    if !status.contains(" 200") {
        bail!("GET {path} on {addr}: {status:?}");
    }
    Ok(Json::parse(body)?)
}

fn parse_metrics(json: Option<&Json>) -> Vec<(String, MetricSnapshot)> {
    json.and_then(|j| cax::obs::metrics_from_json(j).ok())
        .unwrap_or_default()
}

fn metric_of<'a>(metrics: &'a [(String, MetricSnapshot)], name: &str)
                 -> Option<&'a MetricSnapshot> {
    metrics.iter().find(|(n, _)| n == name).map(|(_, m)| m)
}

fn counter_of(metrics: &[(String, MetricSnapshot)], name: &str) -> u64 {
    match metric_of(metrics, name) {
        Some(MetricSnapshot::Counter(v)) => *v,
        _ => 0,
    }
}

fn gauge_of(metrics: &[(String, MetricSnapshot)], name: &str)
            -> (u64, u64) {
    match metric_of(metrics, name) {
        Some(MetricSnapshot::Gauge { value, high_water }) => {
            (*value, *high_water)
        }
        _ => (0, 0),
    }
}

/// Exact p99 of an ns-recorded latency histogram, rendered in ms
/// (`"-"` when the histogram is empty or absent).
fn p99_ms(metrics: &[(String, MetricSnapshot)], name: &str) -> String {
    match metric_of(metrics, name) {
        Some(MetricSnapshot::Histogram(h)) if !h.is_empty() => {
            format!("{:.2}ms", h.quantile(0.99) / 1e6)
        }
        _ => "-".to_string(),
    }
}

fn num_of(json: &Json, key: &str) -> u64 {
    json.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64
}

fn top_header() -> String {
    format!(
        "{:<8} {:<5} {:>5} {:>5} {:>9} {:>10} {:>10} {:>10} {:>8} \
         {:>8} {:>8} {:>13}\n",
        "SHARD", "", "SESS", "PEND", "QUEUE", "p99 wait", "p99 step",
        "STEPS", "dense", "sparse", "hlife", "tiles rc/sk"
    )
}

/// One dashboard row from a worker-shaped metric set.
fn top_row(label: &str, ok: bool, sessions: u64, pending: u64,
           metrics: &[(String, MetricSnapshot)]) -> String {
    let (q_now, q_hw) = gauge_of(metrics, "serve_queue_depth");
    format!(
        "{:<8} {:<5} {:>5} {:>5} {:>9} {:>10} {:>10} {:>10} {:>8} \
         {:>8} {:>8} {:>13}\n",
        label,
        if ok { "up" } else { "stale" },
        sessions,
        pending,
        format!("{q_now}/{q_hw}"),
        p99_ms(metrics, "serve_wait_seconds"),
        p99_ms(metrics, "serve_step_seconds"),
        counter_of(metrics, "serve_session_steps_total"),
        counter_of(metrics, "step_path_dense_total"),
        counter_of(metrics, "step_path_sparse_total"),
        counter_of(metrics, "step_path_hashlife_total"),
        format!(
            "{}/{}",
            counter_of(metrics, "sparse_tiles_recomputed_total"),
            counter_of(metrics, "sparse_tiles_skipped_total")
        ),
    )
}

/// Render one `cax top` frame from a `/metrics.json` document —
/// per-shard rows plus the exact merged FLEET row against a router,
/// one row against a single worker.
fn top_frame(addr: &str) -> Result<String> {
    let json = http_get_json(addr, "/metrics.json")?;
    let mut out = String::new();
    if json.get("router").and_then(Json::as_bool) == Some(true) {
        let shards =
            json.get("shards").and_then(Json::as_arr).unwrap_or(&[]);
        out.push_str(&format!(
            "cax top — {addr} (router, {} shards)\n\n",
            shards.len()
        ));
        out.push_str(&top_header());
        for s in shards {
            let metrics = parse_metrics(s.get("metrics"));
            let label = s
                .get("shard")
                .and_then(Json::as_usize)
                .map_or("?".to_string(), |i| i.to_string());
            let ok = s.get("ok").and_then(Json::as_bool) != Some(false);
            out.push_str(&top_row(&label, ok, num_of(s, "sessions"),
                                  num_of(s, "pending"), &metrics));
        }
        if let Some(merged) = json.get("merged") {
            let metrics = parse_metrics(merged.get("metrics"));
            out.push_str(&top_row("FLEET", true,
                                  num_of(merged, "sessions"),
                                  num_of(merged, "pending"), &metrics));
        }
    } else {
        let metrics = parse_metrics(json.get("metrics"));
        let label = json
            .get("shard")
            .and_then(Json::as_usize)
            .map_or("solo".to_string(), |i| i.to_string());
        out.push_str(&format!(
            "cax top — {addr} (worker, uptime {:.1}s)\n\n",
            json.get("uptime_s").and_then(Json::as_f64).unwrap_or(0.0)
        ));
        out.push_str(&top_header());
        out.push_str(&top_row(&label, true, num_of(&json, "sessions"),
                              num_of(&json, "pending"), &metrics));
    }
    Ok(out)
}

/// `cax top`: a std-only live dashboard over `GET /metrics.json`.
fn cmd_top(cli: &Cli) -> Result<()> {
    let addr = cli.flag("--addr").unwrap_or("127.0.0.1:7878").to_string();
    let interval = std::time::Duration::from_millis(
        cli.flag_usize("--interval-ms", 1000)? as u64,
    );
    let iterations = cli.flag_usize("--iterations", 0)?;
    let mut done = 0usize;
    loop {
        let frame = match top_frame(&addr) {
            Ok(f) => f,
            Err(e) => format!("cax top — {addr}: {e:#}\n"),
        };
        // ANSI clear + home keeps the redraw flicker-free.
        print!("\x1b[2J\x1b[H{frame}");
        std::io::Write::flush(&mut std::io::stdout())?;
        done += 1;
        if iterations != 0 && done >= iterations {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

// ----------------------------------------------------------------- bench

/// `cax bench ...`: BENCH-report tooling (today: `compare`).
fn cmd_bench(cli: &Cli) -> Result<()> {
    match cli.args.get(1).map(String::as_str) {
        Some("compare") => cmd_bench_compare(cli),
        Some(other) => {
            bail!("unknown bench subcommand {other:?} (try `compare`)")
        }
        None => bail!(
            "bench: compare --current FILE --baseline FILE \
             [--threshold R] [--soft]"
        ),
    }
}

/// The bench-history regression gate: diff a fresh `BENCH_*.json`
/// against a committed baseline, row by row on `median_s`.
fn cmd_bench_compare(cli: &Cli) -> Result<()> {
    use cax::metrics::bench_history;
    let current = PathBuf::from(
        cli.flag("--current")
            .context("bench compare: --current FILE")?,
    );
    let baseline = PathBuf::from(
        cli.flag("--baseline")
            .context("bench compare: --baseline FILE")?,
    );
    let threshold = match cli.flag("--threshold") {
        Some(t) => t.parse::<f64>().with_context(|| {
            format!("--threshold wants a ratio, got {t:?}")
        })?,
        None => bench_history::DEFAULT_THRESHOLD,
    };
    let soft = cli.has("--soft");
    let cmp = bench_history::compare_files(&current, &baseline)?;
    println!(
        "bench compare: {} vs baseline {} (threshold +{:.0}%)",
        current.display(),
        baseline.display(),
        100.0 * threshold
    );
    for d in &cmp.deltas {
        let slow = d.slowdown();
        let mark = if slow > threshold {
            "REGRESSED"
        } else if slow < -threshold {
            "improved"
        } else {
            "ok"
        };
        println!(
            "  {:<44} median {:.6}s -> {:.6}s  ({:+.1}%)  {mark}",
            d.label, d.baseline_s, d.current_s, 100.0 * slow
        );
    }
    for label in &cmp.missing {
        println!("  {label:<44} MISSING from current run");
    }
    for label in &cmp.added {
        println!("  {label:<44} new row (no baseline)");
    }
    if cmp.passed(threshold) {
        println!(
            "bench compare: OK ({} rows within +{:.0}%)",
            cmp.deltas.len(),
            100.0 * threshold
        );
        return Ok(());
    }
    let n = cmp.regressions(threshold).len() + cmp.missing.len();
    if soft {
        cax::log_warn!(
            "bench compare: {n} regression(s) beyond +{:.0}% — soft \
             gate, not failing",
            100.0 * threshold
        );
        return Ok(());
    }
    bail!(
        "bench compare: {n} regression(s) beyond +{:.0}%",
        100.0 * threshold
    )
}

// ------------------------------------------------------------------ eval

/// Train-then-evaluate one ARC task on any [`ProgramBackend`]; returns
/// (exact-match, per-pixel) accuracy on the held-out split.
fn arc_task_accuracy(backend: &dyn cax::backend::ProgramBackend,
                     cfg: &TrainCfg, task: Task, seed: u64)
                     -> Result<(f64, f64)> {
    let (train_set, test_set) =
        experiments::arc_split(backend, task, 160, 50, seed)?;
    let run = experiments::train_arc(backend, cfg, task, &train_set)?;
    let acc = evaluator::arc_accuracy(backend, &run.state.params,
                                      &test_set)?;
    let pix = evaluator::arc_pixel_accuracy(backend, &run.state.params,
                                            &test_set)?;
    Ok((acc, pix))
}

fn print_arc_row(task: Task, acc: f64, pix: f64) {
    println!(
        "ARC {:<28} exact-match {:>5.1}%  per-pixel {:>5.1}%  (paper \
         NCA: {:.0}%, GPT-4: {:.0}%)",
        task.name(), 100.0 * acc, 100.0 * pix,
        task.paper_nca_accuracy(), task.gpt4_accuracy()
    );
}

fn cmd_eval(cli: &Cli) -> Result<()> {
    let what = cli.args.get(1).context("eval: arc|mnist|autoenc3d")?.clone();
    let backend = cli
        .flag("--backend")
        .unwrap_or(if cfg!(feature = "pjrt") { "pjrt" } else { "native" });
    match backend {
        "native" => cmd_eval_native(cli, &what),
        "pjrt" => cmd_eval_pjrt(cli, &what),
        other => bail!("unknown --backend {other:?} (native|pjrt)"),
    }
}

/// Hermetic §5.3 evaluation: train the 1D-ARC NCA per task with the
/// native BPTT train step and score the paper's exact-match criterion.
/// `--task all` (the default) reproduces the Table-2 sweep.
fn cmd_eval_native(cli: &Cli, what: &str) -> Result<()> {
    if what != "arc" {
        bail!(
            "the native backend evaluates `arc`; {what:?} needs the pjrt \
             backend (rebuild with --features pjrt and run `make \
             artifacts`)"
        );
    }
    let backend = NativeTrainBackend::new();
    let steps = match cli.flag("--train-steps") {
        Some(s) => s.parse::<usize>()?,
        None => cli.cfg.train.steps,
    };
    let task_flag = cli.flag("--task").unwrap_or("all");
    let tasks: Vec<Task> = if task_flag.eq_ignore_ascii_case("all") {
        Task::ALL.to_vec()
    } else {
        vec![Task::find(task_flag)
            .with_context(|| format!("unknown ARC task {task_flag:?}"))?]
    };
    let cfg = TrainCfg {
        steps,
        seed: cli.cfg.seed as u32,
        // Keep the per-task table readable on full sweeps.
        log_every: if tasks.len() > 1 { 0 } else { cli.cfg.train.log_every },
        out_dir: None,
    };
    println!(
        "1D-ARC natively: {} task(s), {} train steps each (seed {}, {} \
         worker threads)",
        tasks.len(), cfg.steps, cfg.seed, backend.threads()
    );
    let t = Timer::start();
    let mut mean_acc = 0.0;
    for &task in &tasks {
        let (acc, pix) = arc_task_accuracy(&backend, &cfg, task,
                                           cli.cfg.seed)?;
        print_arc_row(task, acc, pix);
        mean_acc += acc;
    }
    if tasks.len() > 1 {
        let n = tasks.len() as f64;
        let gpt4: f64 = tasks.iter().map(|t| t.gpt4_accuracy()).sum();
        let paper: f64 = tasks.iter().map(|t| t.paper_nca_accuracy()).sum();
        println!(
            "mean over {} tasks: exact-match {:.1}%  (paper NCA {:.1}%, \
             GPT-4 {:.1}%)  [{:.1}s]",
            tasks.len(), 100.0 * mean_acc / n, paper / n, gpt4 / n,
            t.elapsed_secs()
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_eval_pjrt(cli: &Cli, what: &str) -> Result<()> {
    let eng = engine(cli)?;
    let steps = match cli.flag("--train-steps") {
        Some(s) => s.parse::<usize>()?,
        None => cli.cfg.train.steps,
    };
    let cfg = TrainCfg {
        steps,
        seed: cli.cfg.seed as u32,
        log_every: cli.cfg.train.log_every,
        out_dir: None,
    };
    match what {
        "arc" => {
            let task_name = cli.flag("--task").unwrap_or("Denoise");
            let task = Task::find(task_name)
                .with_context(|| format!("unknown ARC task {task_name:?}"))?;
            let (acc, pix) =
                arc_task_accuracy(&eng, &cfg, task, cli.cfg.seed)?;
            print_arc_row(task, acc, pix);
        }
        "mnist" => {
            let run = experiments::train_mnist(&eng, &cfg)?;
            let info = eng.manifest().artifact("mnist_eval")?;
            let (h, w) = (info.inputs[1].shape[1], info.inputs[1].shape[2]);
            let digits = mnist::dataset(100, &MnistConfig::for_grid(h, w),
                                        cli.cfg.seed ^ 0xEA1);
            let refs: Vec<&mnist::Digit> = digits.iter().collect();
            let acc = evaluator::mnist_accuracy(&eng, &run.state.params,
                                                &refs, cfg.seed)?;
            println!("self-classifying MNIST: majority-vote accuracy {:.1}% \
                      on 100 held-out digits", 100.0 * acc);
        }
        "autoenc3d" => {
            let run = experiments::train_autoenc3d(&eng, &cfg)?;
            let info = eng.manifest().artifact("autoenc3d_eval")?;
            let (h, w) = (info.inputs[1].shape[1], info.inputs[1].shape[2]);
            let digits = mnist::dataset(32, &MnistConfig::for_grid(h, w),
                                        cli.cfg.seed ^ 0x3D);
            let refs: Vec<&mnist::Digit> = digits.iter().collect();
            let mse = evaluator::autoenc3d_recon_mse(&eng, &run.state.params,
                                                     &refs, cfg.seed)?;
            println!("self-autoencoding MNIST (3D): reconstruction MSE \
                      {mse:.5} on 32 held-out digits");
        }
        other => bail!("unknown eval target {other:?}"),
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_eval_pjrt(_cli: &Cli, what: &str) -> Result<()> {
    bail!(
        "`cax eval {what} --backend pjrt` needs trained neural-CA \
         artifacts; rebuild with --features pjrt (this build evaluates \
         natively: `cax eval arc --backend native`)"
    )
}
