//! Chrome/Perfetto trace-event JSON capture.
//!
//! [`start`] arms a process-wide bounded capture buffer; every span
//! that closes while it is armed ([`record_complete`], called from
//! [`crate::obs::span`]'s drop) and every [`counter`] sample becomes
//! one trace event. [`write`] serializes the capture as Trace Event
//! Format JSON — open the file at <https://ui.perfetto.dev> or
//! `chrome://tracing` to see per-launch kernel spans, scheduler ticks
//! and batch packing on a shared timeline.
//!
//! Event names are the spans' static labels (plain identifiers, so the
//! hand-rolled JSON writer needs no string escaping). Timestamps are
//! microseconds relative to the capture start; thread lanes (`tid`)
//! are small dense ids assigned in first-record order. The buffer is
//! bounded ([`DEFAULT_CAPACITY`] events); once full, further events
//! are counted as dropped rather than growing memory without limit.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// Default capture-buffer bound, in events (~100 bytes each on disk).
pub const DEFAULT_CAPACITY: usize = 1 << 20;

static ACTIVE: AtomicBool = AtomicBool::new(false);

#[derive(Clone, Debug)]
struct Event {
    name: &'static str,
    /// `'X'` = complete span, `'C'` = counter sample.
    ph: char,
    ts_us: f64,
    dur_us: f64,
    tid: u64,
    value: f64,
}

struct Capture {
    t0: Instant,
    events: Vec<Event>,
    capacity: usize,
    dropped: u64,
}

fn capture() -> &'static Mutex<Option<Capture>> {
    static CAP: OnceLock<Mutex<Option<Capture>>> = OnceLock::new();
    CAP.get_or_init(|| Mutex::new(None))
}

fn lock() -> std::sync::MutexGuard<'static, Option<Capture>> {
    capture()
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Small dense per-thread lane id (Perfetto's `tid`), assigned in
/// first-record order.
fn thread_lane() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static LANE: std::cell::Cell<u64> = std::cell::Cell::new(0);
    }
    LANE.with(|c| {
        let mut v = c.get();
        if v == 0 {
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            c.set(v);
        }
        v
    })
}

/// Whether a capture is armed (checked by spans on the hot path).
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Arm a fresh capture with the default buffer bound. Any previous
/// unwritten capture is discarded.
pub fn start() {
    start_with_capacity(DEFAULT_CAPACITY);
}

pub fn start_with_capacity(capacity: usize) {
    let mut guard = lock();
    *guard = Some(Capture {
        t0: Instant::now(),
        events: Vec::new(),
        capacity: capacity.max(1),
        dropped: 0,
    });
    ACTIVE.store(true, Ordering::Relaxed);
}

/// Disarm and drop the capture without writing; returns how many
/// events it held.
pub fn stop() -> usize {
    ACTIVE.store(false, Ordering::Relaxed);
    let mut guard = lock();
    let n = guard.as_ref().map_or(0, |c| c.events.len());
    *guard = None;
    n
}

/// Record one completed span (`ph: "X"`). `name` must be a plain
/// identifier-style label (no quotes or backslashes).
pub fn record_complete(name: &'static str, start: Instant, dur: Duration) {
    if !active() {
        return;
    }
    let tid = thread_lane();
    let mut guard = lock();
    let Some(cap) = guard.as_mut() else { return };
    if cap.events.len() >= cap.capacity {
        cap.dropped += 1;
        return;
    }
    let ts = start
        .checked_duration_since(cap.t0)
        .unwrap_or(Duration::ZERO);
    cap.events.push(Event {
        name,
        ph: 'X',
        ts_us: ts.as_secs_f64() * 1e6,
        dur_us: dur.as_secs_f64() * 1e6,
        tid,
        value: 0.0,
    });
}

/// Record one counter sample (`ph: "C"` — e.g. queue depth over time).
pub fn counter(name: &'static str, value: f64) {
    if !active() {
        return;
    }
    let tid = thread_lane();
    let now = Instant::now();
    let mut guard = lock();
    let Some(cap) = guard.as_mut() else { return };
    if cap.events.len() >= cap.capacity {
        cap.dropped += 1;
        return;
    }
    let ts = now.checked_duration_since(cap.t0).unwrap_or(Duration::ZERO);
    cap.events.push(Event {
        name,
        ph: 'C',
        ts_us: ts.as_secs_f64() * 1e6,
        dur_us: 0.0,
        tid,
        value,
    });
}

/// Disarm the capture and write it as Trace Event Format JSON.
/// Returns the number of events written. Errors if no capture was
/// ever started.
pub fn write(path: &Path) -> Result<usize> {
    ACTIVE.store(false, Ordering::Relaxed);
    let taken = lock().take();
    let Some(cap) = taken else {
        bail!("trace: no capture was started (call trace::start first)")
    };
    let mut out = String::with_capacity(cap.events.len() * 100 + 128);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, e) in cap.events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        match e.ph {
            'C' => out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"cax\",\"ph\":\"C\",\
                 \"pid\":1,\"tid\":{},\"ts\":{:.3},\
                 \"args\":{{\"value\":{}}}}}",
                e.name, e.tid, e.ts_us, e.value
            )),
            _ => out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"cax\",\"ph\":\"X\",\
                 \"pid\":1,\"tid\":{},\"ts\":{:.3},\"dur\":{:.3}}}",
                e.name, e.tid, e.ts_us, e.dur_us
            )),
        }
    }
    out.push_str("]}");
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, out)
        .with_context(|| format!("writing trace {}", path.display()))?;
    if cap.dropped > 0 {
        crate::log_warn!(
            "trace: buffer full — dropped {} events (capacity {})",
            cap.dropped,
            cap.capacity
        );
    }
    Ok(cap.events.len())
}
