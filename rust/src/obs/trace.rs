//! Chrome/Perfetto trace-event JSON capture.
//!
//! [`start`] arms a process-wide bounded capture buffer; every span
//! that closes while it is armed ([`record_complete`], called from
//! [`crate::obs::span`]'s drop) and every [`counter`] sample becomes
//! one trace event. [`write`] serializes the capture as Trace Event
//! Format JSON — open the file at <https://ui.perfetto.dev> or
//! `chrome://tracing` to see per-launch kernel spans, scheduler ticks
//! and batch packing on a shared timeline.
//!
//! Event names are the spans' static labels (plain identifiers, so the
//! hand-rolled JSON writer needs no string escaping). Timestamps are
//! microseconds relative to the capture start; thread lanes (`tid`)
//! are small dense ids assigned in first-record order. The buffer is
//! bounded ([`DEFAULT_CAPACITY`] events); once full, further events
//! are counted as dropped rather than growing memory without limit.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Default capture-buffer bound, in events (~100 bytes each on disk).
pub const DEFAULT_CAPACITY: usize = 1 << 20;

static ACTIVE: AtomicBool = AtomicBool::new(false);

/// Perfetto `pid` lane stamped on every event this process records.
/// Single-process runs and the shard router keep the default (1);
/// workers stamp `shard_index + 2` at startup so a merged fleet trace
/// shows one process row per shard.
static PID: AtomicU64 = AtomicU64::new(1);

/// Set this process's Perfetto `pid` lane (see [`PID`] docs).
pub fn set_pid(pid: u64) {
    PID.store(pid, Ordering::Relaxed);
}

pub fn pid() -> u64 {
    PID.load(Ordering::Relaxed)
}

#[derive(Clone, Debug)]
struct Event {
    name: &'static str,
    /// `'X'` = complete span, `'C'` = counter sample.
    ph: char,
    ts_us: f64,
    dur_us: f64,
    tid: u64,
    value: f64,
    /// Cross-process request id (`X-Cax-Trace`), emitted in `args`.
    trace_id: Option<u64>,
}

struct Capture {
    t0: Instant,
    /// Wall clock at `t0`, µs since the Unix epoch — the shared
    /// timebase [`write_merged`] uses to align captures from
    /// different processes.
    start_unix_us: u64,
    events: Vec<Event>,
    capacity: usize,
    dropped: u64,
}

fn unix_us_now() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros().min(u64::MAX as u128) as u64)
        .unwrap_or(0)
}

fn capture() -> &'static Mutex<Option<Capture>> {
    static CAP: OnceLock<Mutex<Option<Capture>>> = OnceLock::new();
    CAP.get_or_init(|| Mutex::new(None))
}

fn lock() -> std::sync::MutexGuard<'static, Option<Capture>> {
    capture()
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Small dense per-thread lane id (Perfetto's `tid`), assigned in
/// first-record order.
fn thread_lane() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static LANE: std::cell::Cell<u64> = std::cell::Cell::new(0);
    }
    LANE.with(|c| {
        let mut v = c.get();
        if v == 0 {
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            c.set(v);
        }
        v
    })
}

/// Whether a capture is armed (checked by spans on the hot path).
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Arm a fresh capture with the default buffer bound. Any previous
/// unwritten capture is discarded.
pub fn start() {
    start_with_capacity(DEFAULT_CAPACITY);
}

pub fn start_with_capacity(capacity: usize) {
    let mut guard = lock();
    *guard = Some(Capture {
        t0: Instant::now(),
        start_unix_us: unix_us_now(),
        events: Vec::new(),
        capacity: capacity.max(1),
        dropped: 0,
    });
    ACTIVE.store(true, Ordering::Relaxed);
}

/// Whether an unwritten capture exists ([`write`] wants one; the CLI
/// uses this to skip the post-run write when the router already wrote
/// the merged fleet trace).
pub fn pending() -> bool {
    lock().is_some()
}

/// Disarm and drop the capture without writing; returns how many
/// events it held.
pub fn stop() -> usize {
    ACTIVE.store(false, Ordering::Relaxed);
    let mut guard = lock();
    let n = guard.as_ref().map_or(0, |c| c.events.len());
    *guard = None;
    n
}

/// Record one completed span (`ph: "X"`). `name` must be a plain
/// identifier-style label (no quotes or backslashes).
pub fn record_complete(name: &'static str, start: Instant, dur: Duration) {
    record_complete_with_id(name, start, dur, None);
}

/// Record one completed span carrying a cross-process trace id (the
/// router's `X-Cax-Trace` request id) in its `args`, so one proxied
/// request can be followed router → queue → batch → kernel across
/// processes in the merged fleet trace.
pub fn record_complete_with_id(name: &'static str, start: Instant,
                               dur: Duration, trace_id: Option<u64>) {
    if !active() {
        return;
    }
    let tid = thread_lane();
    let mut guard = lock();
    let Some(cap) = guard.as_mut() else { return };
    if cap.events.len() >= cap.capacity {
        cap.dropped += 1;
        return;
    }
    let ts = start
        .checked_duration_since(cap.t0)
        .unwrap_or(Duration::ZERO);
    cap.events.push(Event {
        name,
        ph: 'X',
        ts_us: ts.as_secs_f64() * 1e6,
        dur_us: dur.as_secs_f64() * 1e6,
        tid,
        value: 0.0,
        trace_id,
    });
}

/// Record one counter sample (`ph: "C"` — e.g. queue depth over time).
pub fn counter(name: &'static str, value: f64) {
    if !active() {
        return;
    }
    let tid = thread_lane();
    let now = Instant::now();
    let mut guard = lock();
    let Some(cap) = guard.as_mut() else { return };
    if cap.events.len() >= cap.capacity {
        cap.dropped += 1;
        return;
    }
    let ts = now.checked_duration_since(cap.t0).unwrap_or(Duration::ZERO);
    cap.events.push(Event {
        name,
        ph: 'C',
        ts_us: ts.as_secs_f64() * 1e6,
        dur_us: 0.0,
        tid,
        value,
        trace_id: None,
    });
}

/// Serialize one event, stamped with `pid`, timestamps shifted onto
/// the merged timebase by `shift_us`.
fn push_event(out: &mut String, e: &Event, pid: u64, shift_us: f64) {
    match e.ph {
        'C' => out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"cax\",\"ph\":\"C\",\
             \"pid\":{pid},\"tid\":{},\"ts\":{:.3},\
             \"args\":{{\"value\":{}}}}}",
            e.name, e.tid, e.ts_us + shift_us, e.value
        )),
        _ => match e.trace_id {
            Some(id) => out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"cax\",\"ph\":\"X\",\
                 \"pid\":{pid},\"tid\":{},\"ts\":{:.3},\"dur\":{:.3},\
                 \"args\":{{\"trace\":{id}}}}}",
                e.name, e.tid, e.ts_us + shift_us, e.dur_us
            )),
            None => out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"cax\",\"ph\":\"X\",\
                 \"pid\":{pid},\"tid\":{},\"ts\":{:.3},\"dur\":{:.3}}}",
                e.name, e.tid, e.ts_us + shift_us, e.dur_us
            )),
        },
    }
}

fn write_file(path: &Path, out: String) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, out)
        .with_context(|| format!("writing trace {}", path.display()))
}

/// Disarm the capture and write it as Trace Event Format JSON.
/// Returns the number of events written. Errors if no capture was
/// ever started.
pub fn write(path: &Path) -> Result<usize> {
    ACTIVE.store(false, Ordering::Relaxed);
    let taken = lock().take();
    let Some(cap) = taken else {
        bail!("trace: no capture was started (call trace::start first)")
    };
    let mut out = String::with_capacity(cap.events.len() * 100 + 128);
    out.push_str(&format!(
        "{{\"displayTimeUnit\":\"ms\",\"captureStartUnixUs\":{},\
         \"traceEvents\":[",
        cap.start_unix_us
    ));
    let pid = pid();
    for (i, e) in cap.events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_event(&mut out, e, pid, 0.0);
    }
    out.push_str("]}");
    write_file(path, out)?;
    if cap.dropped > 0 {
        crate::log_warn!(
            "trace: buffer full — dropped {} events (capacity {})",
            cap.dropped,
            cap.capacity
        );
    }
    Ok(cap.events.len())
}

/// Disarm this process's capture and merge it with per-worker trace
/// files (each produced by [`write`] inside a worker process) into
/// one fleet Perfetto file. `workers` lists `(pid, process label,
/// trace file)` per shard. Worker timestamps are re-based onto a
/// shared wall-clock timebase (the minimum `captureStartUnixUs`
/// across all captures) and every worker event is re-stamped with its
/// shard's `pid`; `process_name` metadata rows label each lane.
/// Worker tmp files are removed after a successful read; an
/// unreadable file (crashed shard) is skipped with a warning, never
/// fatal. Returns the total number of events written.
pub fn write_merged(path: &Path,
                    workers: &[(u64, String, PathBuf)]) -> Result<usize> {
    ACTIVE.store(false, Ordering::Relaxed);
    let taken = lock().take();
    let Some(cap) = taken else {
        bail!("trace: no capture was started (call trace::start first)")
    };

    let mut parsed: Vec<(u64, String, u64, Vec<Json>)> = Vec::new();
    for (worker_pid, label, file) in workers {
        let json = match std::fs::read_to_string(file)
            .map_err(anyhow::Error::from)
            .and_then(|text| Ok(Json::parse(&text)?))
        {
            Ok(j) => j,
            Err(e) => {
                crate::log_warn!(
                    "trace: skipping {label} capture {}: {e}",
                    file.display()
                );
                continue;
            }
        };
        let start_unix = json
            .get("captureStartUnixUs")
            .and_then(Json::as_f64)
            .unwrap_or(cap.start_unix_us as f64) as u64;
        let events = json
            .get("traceEvents")
            .and_then(Json::as_arr)
            .map(<[Json]>::to_vec)
            .unwrap_or_default();
        let _ = std::fs::remove_file(file);
        parsed.push((*worker_pid, label.clone(), start_unix, events));
    }

    let base = parsed
        .iter()
        .map(|p| p.2)
        .chain(std::iter::once(cap.start_unix_us))
        .min()
        .unwrap_or(0);
    let own_pid = pid();

    let mut out = String::with_capacity(cap.events.len() * 100 + 4096);
    out.push_str(&format!(
        "{{\"displayTimeUnit\":\"ms\",\"captureStartUnixUs\":{base},\
         \"traceEvents\":["
    ));
    let mut first = true;
    let mut sep = |out: &mut String| {
        if !first {
            out.push(',');
        }
        first = false;
    };
    let mut lanes = vec![(own_pid, "router".to_string())];
    lanes.extend(parsed.iter().map(|p| (p.0, p.1.clone())));
    for (lane_pid, label) in &lanes {
        sep(&mut out);
        out.push_str(&format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{lane_pid},\
             \"args\":{{\"name\":\"{label}\"}}}}"
        ));
    }
    let mut total = 0usize;
    let own_shift = (cap.start_unix_us - base) as f64;
    for e in &cap.events {
        sep(&mut out);
        push_event(&mut out, e, own_pid, own_shift);
        total += 1;
    }
    for (worker_pid, _, start_unix, events) in &parsed {
        let shift = (start_unix - base) as f64;
        for ev in events {
            let mut map = match ev {
                Json::Obj(m) => m.clone(),
                _ => continue,
            };
            if let Some(ts) = map.get("ts").and_then(Json::as_f64) {
                map.insert("ts".to_string(), Json::Num(ts + shift));
            }
            map.insert("pid".to_string(), Json::from(*worker_pid));
            sep(&mut out);
            out.push_str(&Json::Obj(map).to_string_compact());
            total += 1;
        }
    }
    out.push_str("]}");
    write_file(path, out)?;
    if cap.dropped > 0 {
        crate::log_warn!(
            "trace: buffer full — dropped {} events (capacity {})",
            cap.dropped,
            cap.capacity
        );
    }
    Ok(total)
}
