//! Prometheus text exposition (format 0.0.4) over [`Registry`]
//! snapshots — the `GET /metrics` body of `cax serve`.
//!
//! Conventions: every exposed name gets the `cax_` prefix here;
//! histograms whose base name ends in `_seconds` were recorded in
//! nanoseconds and are exposed in seconds (buckets, sum); other
//! histograms (batch sizes, queue depths) expose raw values on a
//! power-of-two `le` ladder. Cumulative `_bucket{le}` counts are
//! computed from the log-bucketed histogram at its own resolution, so
//! they are monotone and end exactly at `_count` for `le="+Inf"`.

use crate::obs::histogram::{HistogramSnapshot, MetricSnapshot, Registry};

/// The `Content-Type` of the text exposition format.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4";

const PREFIX: &str = "cax_";

/// `le` ladder (in ns) for `_seconds` histograms: 1µs .. 60s.
const SECONDS_BOUNDS_NS: [u64; 12] = [
    1_000,
    10_000,
    100_000,
    500_000,
    1_000_000,
    5_000_000,
    10_000_000,
    50_000_000,
    100_000_000,
    1_000_000_000,
    10_000_000_000,
    60_000_000_000,
];

/// `le` ladder for raw-valued histograms (batch sizes, depths).
const VALUE_BOUNDS: [u64; 12] =
    [1, 2, 4, 8, 16, 32, 64, 128, 256, 1024, 4096, 16384];

/// Incremental writer for one exposition body.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    pub fn new() -> PromWriter {
        PromWriter::default()
    }

    pub fn counter(&mut self, name: &str, value: u64) {
        self.out.push_str(&format!(
            "# TYPE {PREFIX}{name} counter\n{PREFIX}{name} {value}\n"
        ));
    }

    pub fn gauge(&mut self, name: &str, value: f64) {
        self.out.push_str(&format!(
            "# TYPE {PREFIX}{name} gauge\n{PREFIX}{name} {value}\n"
        ));
    }

    pub fn histogram(&mut self, name: &str, snap: &HistogramSnapshot) {
        let seconds = name.ends_with("_seconds");
        let bounds: &[u64] =
            if seconds { &SECONDS_BOUNDS_NS } else { &VALUE_BOUNDS };
        self.out
            .push_str(&format!("# TYPE {PREFIX}{name} histogram\n"));
        for &b in bounds {
            let le = if seconds {
                format!("{}", b as f64 * 1e-9)
            } else {
                format!("{b}")
            };
            self.out.push_str(&format!(
                "{PREFIX}{name}_bucket{{le=\"{le}\"}} {}\n",
                snap.cumulative_le(b)
            ));
        }
        self.out.push_str(&format!(
            "{PREFIX}{name}_bucket{{le=\"+Inf\"}} {}\n",
            snap.count
        ));
        let sum =
            if seconds { snap.sum as f64 * 1e-9 } else { snap.sum as f64 };
        self.out
            .push_str(&format!("{PREFIX}{name}_sum {sum}\n"));
        self.out
            .push_str(&format!("{PREFIX}{name}_count {}\n", snap.count));
    }

    /// Append every metric of a registry, in name order. Gauges also
    /// expose their high-water mark as `{name}_high_water`.
    pub fn registry(&mut self, reg: &Registry) {
        for (name, metric) in reg.snapshot() {
            match metric {
                MetricSnapshot::Counter(v) => self.counter(&name, v),
                MetricSnapshot::Gauge { value, high_water } => {
                    self.gauge(&name, value as f64);
                    self.gauge(&format!("{name}_high_water"),
                               high_water as f64);
                }
                MetricSnapshot::Histogram(s) => self.histogram(&name, &s),
            }
        }
    }

    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn exposition_shape() {
        let reg = Registry::new();
        reg.counter("reqs_total").add(7);
        reg.gauge("depth").set(3);
        let h = reg.histogram("wait_seconds");
        h.record_duration(Duration::from_micros(50));
        h.record_duration(Duration::from_millis(20));
        let mut w = PromWriter::new();
        w.registry(&reg);
        let text = w.finish();
        assert!(text.contains("# TYPE cax_reqs_total counter\n"));
        assert!(text.contains("cax_reqs_total 7\n"));
        assert!(text.contains("cax_depth 3\n"));
        assert!(text.contains("cax_depth_high_water 3\n"));
        assert!(text.contains("# TYPE cax_wait_seconds histogram\n"));
        assert!(text.contains("cax_wait_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("cax_wait_seconds_count 2\n"));
        // 50µs fits under the 100µs bound; 20ms does not.
        assert!(text.contains("cax_wait_seconds_bucket{le=\"0.0001\"} 1\n"));
        // Bucket counts are monotone down the ladder.
        let counts: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("cax_wait_seconds_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]));
    }
}
