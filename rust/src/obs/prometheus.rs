//! Prometheus text exposition (format 0.0.4) over [`Registry`]
//! snapshots — the `GET /metrics` body of `cax serve`.
//!
//! Conventions: every exposed name gets the `cax_` prefix here;
//! histograms whose base name ends in `_seconds` were recorded in
//! nanoseconds and are exposed in seconds (buckets, sum); other
//! histograms (batch sizes, queue depths) expose raw values on a
//! power-of-two `le` ladder. Cumulative `_bucket{le}` counts are
//! computed from the log-bucketed histogram at its own resolution, so
//! they are monotone and end exactly at `_count` for `le="+Inf"`.

use crate::obs::histogram::{HistogramSnapshot, MetricSnapshot, Registry};

/// The `Content-Type` of the text exposition format.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4";

const PREFIX: &str = "cax_";

/// `le` ladder (in ns) for `_seconds` histograms: 1µs .. 60s.
const SECONDS_BOUNDS_NS: [u64; 12] = [
    1_000,
    10_000,
    100_000,
    500_000,
    1_000_000,
    5_000_000,
    10_000_000,
    50_000_000,
    100_000_000,
    1_000_000_000,
    10_000_000_000,
    60_000_000_000,
];

/// `le` ladder for raw-valued histograms (batch sizes, depths).
const VALUE_BOUNDS: [u64; 12] =
    [1, 2, 4, 8, 16, 32, 64, 128, 256, 1024, 4096, 16384];

/// Incremental writer for one exposition body.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    pub fn new() -> PromWriter {
        PromWriter::default()
    }

    fn type_line(&mut self, name: &str, kind: &str) {
        self.out
            .push_str(&format!("# TYPE {PREFIX}{name} {kind}\n"));
    }

    /// One sample line: `cax_{name} v` or `cax_{name}{labels} v`.
    fn sample(&mut self, name: &str, labels: &str, value: &str) {
        if labels.is_empty() {
            self.out.push_str(&format!("{PREFIX}{name} {value}\n"));
        } else {
            self.out
                .push_str(&format!("{PREFIX}{name}{{{labels}}} {value}\n"));
        }
    }

    pub fn counter(&mut self, name: &str, value: u64) {
        self.type_line(name, "counter");
        self.counter_series(name, "", value);
    }

    /// One labeled counter sample with no `# TYPE` line. `labels` is
    /// pre-formatted (`shard="0"`); call only after
    /// [`counter`](Self::counter) / [`metric`](Self::metric) has
    /// opened the family, so every family keeps a single `# TYPE`.
    pub fn counter_series(&mut self, name: &str, labels: &str, value: u64) {
        self.sample(name, labels, &format!("{value}"));
    }

    pub fn gauge(&mut self, name: &str, value: f64) {
        self.type_line(name, "gauge");
        self.gauge_series(name, "", value);
    }

    /// Labeled gauge sample, no `# TYPE` (see
    /// [`counter_series`](Self::counter_series)).
    pub fn gauge_series(&mut self, name: &str, labels: &str, value: f64) {
        self.sample(name, labels, &format!("{value}"));
    }

    pub fn histogram(&mut self, name: &str, snap: &HistogramSnapshot) {
        self.type_line(name, "histogram");
        self.histogram_series(name, "", snap);
    }

    /// Labeled histogram series (`_bucket{labels,le=..}`, `_sum`,
    /// `_count`), no `# TYPE` (see
    /// [`counter_series`](Self::counter_series)).
    pub fn histogram_series(&mut self, name: &str, labels: &str,
                            snap: &HistogramSnapshot) {
        let seconds = name.ends_with("_seconds");
        let bounds: &[u64] =
            if seconds { &SECONDS_BOUNDS_NS } else { &VALUE_BOUNDS };
        let comma = if labels.is_empty() { "" } else { "," };
        for &b in bounds {
            let le = if seconds {
                format!("{}", b as f64 * 1e-9)
            } else {
                format!("{b}")
            };
            self.out.push_str(&format!(
                "{PREFIX}{name}_bucket{{{labels}{comma}le=\"{le}\"}} {}\n",
                snap.cumulative_le(b)
            ));
        }
        self.out.push_str(&format!(
            "{PREFIX}{name}_bucket{{{labels}{comma}le=\"+Inf\"}} {}\n",
            snap.count
        ));
        let sum =
            if seconds { snap.sum as f64 * 1e-9 } else { snap.sum as f64 };
        self.sample(&format!("{name}_sum"), labels, &format!("{sum}"));
        self.sample(&format!("{name}_count"), labels,
                    &format!("{}", snap.count));
    }

    /// One complete family from a plain-value snapshot (`# TYPE` plus
    /// the unlabeled samples; gauges also expose `{name}_high_water`).
    pub fn metric(&mut self, name: &str, snap: &MetricSnapshot) {
        match snap {
            MetricSnapshot::Counter(v) => self.counter(name, *v),
            MetricSnapshot::Gauge { value, high_water } => {
                self.gauge(name, *value as f64);
                self.gauge(&format!("{name}_high_water"),
                           *high_water as f64);
            }
            MetricSnapshot::Histogram(s) => self.histogram(name, s),
        }
    }

    /// One fleet family: `# TYPE`, the merged (unlabeled) sample, then
    /// a `shard="i"` series per shard — all grouped so the page stays
    /// a single valid exposition (gauges keep their `_high_water`
    /// companion family contiguous too). The merged sample comes from
    /// raw-bucket merging, so its quantiles are exact fleet
    /// quantiles, never averages of per-shard percentiles.
    pub fn metric_fleet(&mut self, name: &str, merged: &MetricSnapshot,
                        shards: &[(u64, MetricSnapshot)]) {
        match merged {
            MetricSnapshot::Counter(v) => {
                self.counter(name, *v);
                for (i, shard) in shards {
                    if let MetricSnapshot::Counter(v) = shard {
                        self.counter_series(name, &format!("shard=\"{i}\""),
                                            *v);
                    }
                }
            }
            MetricSnapshot::Gauge { value, high_water } => {
                self.gauge(name, *value as f64);
                for (i, shard) in shards {
                    if let MetricSnapshot::Gauge { value, .. } = shard {
                        self.gauge_series(name, &format!("shard=\"{i}\""),
                                          *value as f64);
                    }
                }
                let hw_name = format!("{name}_high_water");
                self.gauge(&hw_name, *high_water as f64);
                for (i, shard) in shards {
                    if let MetricSnapshot::Gauge { high_water, .. } = shard {
                        self.gauge_series(&hw_name,
                                          &format!("shard=\"{i}\""),
                                          *high_water as f64);
                    }
                }
            }
            MetricSnapshot::Histogram(s) => {
                self.histogram(name, s);
                for (i, shard) in shards {
                    if let MetricSnapshot::Histogram(s) = shard {
                        self.histogram_series(name,
                                              &format!("shard=\"{i}\""), s);
                    }
                }
            }
        }
    }

    /// Append every metric of a registry, in name order. Gauges also
    /// expose their high-water mark as `{name}_high_water`.
    pub fn registry(&mut self, reg: &Registry) {
        for (name, metric) in reg.snapshot() {
            self.metric(&name, &metric);
        }
    }

    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn exposition_shape() {
        let reg = Registry::new();
        reg.counter("reqs_total").add(7);
        reg.gauge("depth").set(3);
        let h = reg.histogram("wait_seconds");
        h.record_duration(Duration::from_micros(50));
        h.record_duration(Duration::from_millis(20));
        let mut w = PromWriter::new();
        w.registry(&reg);
        let text = w.finish();
        assert!(text.contains("# TYPE cax_reqs_total counter\n"));
        assert!(text.contains("cax_reqs_total 7\n"));
        assert!(text.contains("cax_depth 3\n"));
        assert!(text.contains("cax_depth_high_water 3\n"));
        assert!(text.contains("# TYPE cax_wait_seconds histogram\n"));
        assert!(text.contains("cax_wait_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("cax_wait_seconds_count 2\n"));
        // 50µs fits under the 100µs bound; 20ms does not.
        assert!(text.contains("cax_wait_seconds_bucket{le=\"0.0001\"} 1\n"));
        // Bucket counts are monotone down the ladder.
        let counts: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("cax_wait_seconds_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn fleet_family_groups_merged_and_labeled_series() {
        let a = Registry::new();
        a.counter("reqs_total").add(3);
        a.gauge("depth").set(2);
        a.histogram("wait_seconds")
            .record_duration(Duration::from_micros(50));
        let b = Registry::new();
        b.counter("reqs_total").add(4);
        b.gauge("depth").set(5);
        b.histogram("wait_seconds")
            .record_duration(Duration::from_millis(20));

        let mut w = PromWriter::new();
        for ((name, snap_a), (_, snap_b)) in
            a.snapshot().into_iter().zip(b.snapshot())
        {
            let mut merged = snap_a.clone();
            merged.merge_from(&snap_b);
            w.metric_fleet(&name, &merged, &[(0, snap_a), (1, snap_b)]);
        }
        let text = w.finish();
        // Merged total plus one labeled series per shard.
        assert!(text.contains("cax_reqs_total 7\n"));
        assert!(text.contains("cax_reqs_total{shard=\"0\"} 3\n"));
        assert!(text.contains("cax_reqs_total{shard=\"1\"} 4\n"));
        // Gauges sum now-values and keep per-shard/_high_water series.
        assert!(text.contains("cax_depth 7\n"));
        assert!(text.contains("cax_depth{shard=\"1\"} 5\n"));
        assert!(text.contains("cax_depth_high_water{shard=\"0\"} 2\n"));
        // Histogram counts add; labeled buckets carry both labels.
        assert!(text.contains("cax_wait_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains(
            "cax_wait_seconds_bucket{shard=\"0\",le=\"+Inf\"} 1\n"
        ));
        assert!(text.contains("cax_wait_seconds_count{shard=\"1\"} 1\n"));
        // Exactly one # TYPE line per family, ahead of all its samples.
        for family in
            ["cax_reqs_total", "cax_depth ", "cax_wait_seconds histogram"]
        {
            let n = text
                .lines()
                .filter(|l| l.starts_with("# TYPE") && l.contains(family))
                .count();
            assert_eq!(n, 1, "family {family:?} must keep a single TYPE");
        }
    }
}
