//! A tiny leveled stderr logger with a `CAX_LOG` env filter.
//!
//! `CAX_LOG=error|warn|info|debug` picks the maximum level printed
//! (default `info`). Output goes to stderr as `[cax:LEVEL] message`,
//! keeping stdout clean for machine-parsed command output (e.g. the
//! `cax serve` listening line). Use the crate-level macros:
//!
//! ```
//! cax::log_info!("drained {} sessions", 3);
//! cax::log_debug!("this prints only under CAX_LOG=debug");
//! ```

use std::sync::atomic::{AtomicU8, AtomicU64, Ordering};

/// Log severity; smaller = more severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    pub fn parse(text: &str) -> Option<Level> {
        match text.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

const UNSET: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

fn decode(v: u8) -> Level {
    match v {
        0 => Level::Error,
        1 => Level::Warn,
        3 => Level::Debug,
        _ => Level::Info,
    }
}

/// The active maximum level, lazily read from `CAX_LOG` on first use.
pub fn level() -> Level {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != UNSET {
        return decode(v);
    }
    let from_env = std::env::var("CAX_LOG")
        .ok()
        .and_then(|t| Level::parse(&t))
        .unwrap_or(Level::Info);
    LEVEL.store(from_env as u8, Ordering::Relaxed);
    from_env
}

/// Override the level programmatically (tests, embedding callers).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

const SHARD_UNSET: u64 = u64::MAX;
static SHARD: AtomicU64 = AtomicU64::new(SHARD_UNSET);

/// Stamp this process's shard index into the logger: every stderr
/// line gains a `[shard i]` prefix. Fleet workers call this at
/// startup so direct worker stderr (crash logs, `--state-dir`
/// recovery messages) stays attributable even when it doesn't flow
/// through the router's stdout-forwarding prefix.
pub fn set_shard(index: u64) {
    SHARD.store(index, Ordering::Relaxed);
}

/// The shard index stamped by [`set_shard`], if any.
pub fn shard() -> Option<u64> {
    match SHARD.load(Ordering::Relaxed) {
        SHARD_UNSET => None,
        i => Some(i),
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// The macro backend; prefer `log_error!`..`log_debug!`.
pub fn write(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        match shard() {
            Some(i) => eprintln!("[shard {i}] [cax:{}] {args}", l.name()),
            None => eprintln!("[cax:{}] {args}", l.name()),
        }
    }
}

#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Error,
                                format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Warn,
                                format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Info,
                                format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::obs::log::write($crate::obs::log::Level::Debug,
                                format_args!($($t)*))
    };
}
