//! Scoped RAII span timers with a static-label discipline.
//!
//! Instrumenting a hot kernel is one guard:
//!
//! ```
//! let _span = cax::obs::span("kernel_life");
//! // ... the launch ...
//! // drop records into the global `kernel_life_seconds` histogram
//! // and, when a trace capture is active, emits a trace event.
//! ```
//!
//! Labels are `&'static str` by type: span creation never allocates or
//! formats, so the on-path cost is two relaxed atomic loads plus (when
//! recording) two `Instant` reads and one histogram record. With
//! recording off and no trace active a span is a no-op — no clock
//! read at all. Spans only *time* work; they never touch the data a
//! kernel computes, so instrumented trajectories stay bit-identical
//! (asserted by the serve bit-identity suite, which runs with
//! recording on).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use crate::obs::histogram::Registry;
use crate::obs::trace;

/// Recording defaults ON: a freshly started server reports metrics
/// without opt-in. The overhead bench toggles it off to measure the
/// no-op path.
static RECORDING: AtomicBool = AtomicBool::new(true);

/// Globally enable/disable span recording (trace capture is controlled
/// separately by [`trace::start`]).
pub fn set_recording(on: bool) {
    RECORDING.store(on, Ordering::Relaxed);
}

pub fn recording() -> bool {
    RECORDING.load(Ordering::Relaxed)
}

/// A live span; created by [`span`], records on drop.
#[must_use = "a span times its scope — bind it: `let _span = obs::span(..)`"]
pub struct Span {
    label: &'static str,
    start: Option<Instant>,
    trace_id: Option<u64>,
}

/// Open a span. `label` is the metric base name: drop records into the
/// global registry's `{label}_seconds` histogram.
pub fn span(label: &'static str) -> Span {
    span_with_id(label, None)
}

/// Open a span carrying a cross-process trace id (the `X-Cax-Trace`
/// request id a worker adopted from the router). Metrics are
/// unaffected; when a trace capture is armed the id rides in the
/// event's `args.trace`, tying the worker's queue/batch/kernel spans
/// to the router's proxy span for the same request.
pub fn span_with_id(label: &'static str, trace_id: Option<u64>) -> Span {
    let armed = recording() || trace::active();
    Span {
        label,
        start: if armed { Some(Instant::now()) } else { None },
        trace_id,
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let dur = start.elapsed();
        if recording() {
            Registry::global()
                .histogram(&format!("{}_seconds", self.label))
                .record_duration(dur);
        }
        trace::record_complete_with_id(self.label, start, dur,
                                       self.trace_id);
    }
}
