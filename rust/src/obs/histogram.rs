//! Lock-free log-bucketed histograms, counters and gauges, in a named
//! [`Registry`].
//!
//! The histogram is HDR-style: values (u64, by convention nanoseconds
//! for `*_seconds`-named metrics) land in log-linear buckets — 32
//! sub-buckets per power of two — so recording is two atomic adds and
//! the worst-case relative quantile error is bounded by half a
//! sub-bucket width (< 1.6%). Buckets are `AtomicU64`s: many threads
//! record concurrently with no locks, and histograms merge bucket-wise
//! (merge is associative and commutative; property-checked in
//! `tests/obs_props.rs`).
//!
//! Quantile queries go through the same rank convention
//! ([`percentile_rank`]) as [`crate::util::timer::percentile`], so a
//! `Stats::p99` over raw samples and a `Histogram::quantile(0.99)`
//! over the same data agree up to bucket resolution.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::{obj, Json};
use crate::util::timer::percentile_rank;

/// Read a u64 out of a JSON number. `util::json` stores numbers as
/// f64, which is exact for integers below 2^53 — ns sums stay exact
/// for ~104 days of accumulated time, and counts effectively forever.
fn json_u64(json: &Json, key: &str) -> Result<u64> {
    let v = json
        .get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("metric snapshot: missing {key:?}"))?;
    if !(0.0..=9.007_199_254_740_992e15).contains(&v) {
        bail!("metric snapshot: {key} = {v} outside exact u64 range");
    }
    Ok(v as u64)
}

/// Sub-bucket resolution: each power of two splits into `2^SUB_BITS`
/// linear sub-buckets.
pub const SUB_BITS: u32 = 5;
const SUB: u64 = 1 << SUB_BITS;
/// Total bucket count covering the full u64 range: `SUB` linear buckets
/// for values below `SUB`, then 32 sub-buckets for each of the
/// remaining octaves.
pub const NUM_BUCKETS: usize = (64 - SUB_BITS as usize + 1) * SUB as usize;

/// Bucket index of a value; monotone in `v` and total over u64.
pub fn bucket_index(v: u64) -> usize {
    if v < SUB {
        v as usize
    } else {
        let exp = 63 - v.leading_zeros();
        let sub = ((v >> (exp - SUB_BITS)) - SUB) as usize;
        ((exp - SUB_BITS) as usize + 1) * SUB as usize + sub
    }
}

/// Half-open value range `[lo, hi)` covered by a bucket index.
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    let sub = SUB as usize;
    if idx < sub {
        (idx as u64, idx as u64 + 1)
    } else {
        let exp = (idx / sub - 1) as u32 + SUB_BITS;
        let off = (idx % sub) as u64;
        let width = 1u64 << (exp - SUB_BITS);
        let lo = (SUB + off) * width;
        (lo, lo.saturating_add(width))
    }
}

/// A mergeable, lock-free latency/value histogram.
///
/// `record` is wait-free (five relaxed atomic ops) and safe from any
/// thread; reads take a [`snapshot`](Self::snapshot) and query that.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let mut buckets = Vec::with_capacity(NUM_BUCKETS);
        for _ in 0..NUM_BUCKETS {
            buckets.push(AtomicU64::new(0));
        }
        Histogram {
            buckets,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds (the `*_seconds` convention:
    /// stored as ns, exposed as seconds).
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Add every recorded value of `other` into `self`, bucket-wise.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min
            .fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max
            .fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// A consistent-enough copy for queries (individual bucket loads
    /// are relaxed; concurrent recording may skew totals by in-flight
    /// records, which is fine for monitoring reads).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }

    /// Convenience: quantile straight off a fresh snapshot.
    pub fn quantile(&self, q: f64) -> f64 {
        self.snapshot().quantile(q)
    }

    /// Rebuild a live histogram from a plain-value snapshot (the
    /// receiving half of the fleet scrape: a worker's `/metrics.json`
    /// snapshot becomes a mergeable histogram again). Rebuilding then
    /// [`merge_from`](Self::merge_from)-ing is bit-identical to having
    /// merged the original histograms directly (`tests/obs_props.rs`).
    pub fn from_snapshot(snap: &HistogramSnapshot) -> Histogram {
        let h = Histogram::new();
        for (mine, &theirs) in h.buckets.iter().zip(&snap.buckets) {
            if theirs > 0 {
                mine.store(theirs, Ordering::Relaxed);
            }
        }
        h.count.store(snap.count, Ordering::Relaxed);
        h.sum.store(snap.sum, Ordering::Relaxed);
        h.min.store(snap.min, Ordering::Relaxed);
        h.max.store(snap.max, Ordering::Relaxed);
        h
    }
}

/// Plain-value copy of a [`Histogram`] for queries and exposition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
}

impl HistogramSnapshot {
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile estimate (`q` in `[0, 1]`), using the shared
    /// [`percentile_rank`] convention over bucket representatives.
    /// Empty histograms report 0.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let (lo, hi, frac) = percentile_rank(self.count as usize, q);
        let a = self.value_at_rank(lo as u64);
        if frac == 0.0 || lo == hi {
            return a;
        }
        let b = self.value_at_rank(hi as u64);
        a * (1.0 - frac) + b * frac
    }

    /// Representative value of the `rank`-th (0-based) recorded sample
    /// in sorted order: the midpoint of its bucket, clamped to the
    /// observed min/max so the tails stay exact.
    fn value_at_rank(&self, rank: u64) -> f64 {
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen > rank {
                let (lo, hi) = bucket_bounds(i);
                let rep = (lo as f64 + (hi - 1) as f64) / 2.0;
                return rep.clamp(self.min as f64, self.max as f64);
            }
        }
        self.max as f64
    }

    /// How many recorded values are certainly `<= bound` (counts whole
    /// buckets whose upper edge fits — the Prometheus `_bucket{le}`
    /// cumulative, approximated at bucket resolution).
    pub fn cumulative_le(&self, bound: u64) -> u64 {
        let mut total = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            let (lo, hi) = bucket_bounds(i);
            if hi - 1 <= bound {
                total += c;
            } else if lo > bound {
                break;
            }
        }
        total
    }

    /// Bucket-wise merge on plain values — identical semantics to
    /// [`Histogram::merge_from`], for merging scraped snapshots
    /// without going back through atomics. Because the merge is on
    /// raw bucket counts, fleet quantiles computed from the merged
    /// snapshot are *exact* (equal to the quantiles of the union of
    /// the shards' samples at bucket resolution) — never an average
    /// of per-shard percentiles.
    pub fn merge_from(&mut self, other: &HistogramSnapshot) {
        for (mine, &theirs) in
            self.buckets.iter_mut().zip(&other.buckets)
        {
            *mine += theirs;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Exact JSON form: the raw bucket counts (sparse `[index, count]`
    /// pairs), count, sum and the observed min/max. `min`/`max` are
    /// omitted for an empty histogram (whose internal sentinels,
    /// `u64::MAX`/`0`, are not exactly representable as JSON numbers).
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                Json::Arr(vec![Json::from(i), Json::Num(c as f64)])
            })
            .collect();
        let mut fields = vec![
            ("type", Json::from("histogram")),
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum as f64)),
        ];
        if self.count > 0 {
            fields.push(("min", Json::Num(self.min as f64)));
            fields.push(("max", Json::Num(self.max as f64)));
        }
        fields.push(("buckets", Json::Arr(buckets)));
        obj(fields)
    }

    /// Parse the [`to_json`](Self::to_json) form back. Round-tripping
    /// a snapshot through JSON is bit-identical (`PartialEq`) for all
    /// values below 2^53 (property-checked in `tests/obs_props.rs`).
    pub fn from_json(json: &Json) -> Result<HistogramSnapshot> {
        let count = json_u64(json, "count")?;
        let sum = json_u64(json, "sum")?;
        let (min, max) = if count == 0 {
            (u64::MAX, 0)
        } else {
            (json_u64(json, "min")?, json_u64(json, "max")?)
        };
        let mut buckets = vec![0u64; NUM_BUCKETS];
        let pairs = json
            .get("buckets")
            .and_then(Json::as_arr)
            .context("histogram snapshot: missing buckets array")?;
        for pair in pairs {
            let pair =
                pair.as_arr().context("bucket entry is not a pair")?;
            let (idx, c) = match pair.as_slice() {
                [i, c] => (
                    i.as_usize().context("bucket index")?,
                    c.as_f64().context("bucket count")? as u64,
                ),
                _ => bail!("bucket entry is not an [index, count] pair"),
            };
            if idx >= NUM_BUCKETS {
                bail!("bucket index {idx} out of range");
            }
            buckets[idx] += c;
        }
        Ok(HistogramSnapshot { buckets, count, sum, min, max })
    }
}

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.value.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-value gauge that also tracks its high-water mark.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
    high_water: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
        self.high_water.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    pub fn high_water(&self) -> u64 {
        self.high_water.load(Ordering::Relaxed)
    }
}

/// One named metric handle.
#[derive(Clone, Debug)]
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Plain-value copy of one metric for exposition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MetricSnapshot {
    Counter(u64),
    Gauge { value: u64, high_water: u64 },
    Histogram(HistogramSnapshot),
}

impl MetricSnapshot {
    /// Exact JSON form, tagged by `type` (the `/metrics.json` wire
    /// format the shard router scrapes and merges).
    pub fn to_json(&self) -> Json {
        match self {
            MetricSnapshot::Counter(v) => obj(vec![
                ("type", Json::from("counter")),
                ("value", Json::Num(*v as f64)),
            ]),
            MetricSnapshot::Gauge { value, high_water } => obj(vec![
                ("type", Json::from("gauge")),
                ("value", Json::Num(*value as f64)),
                ("high_water", Json::Num(*high_water as f64)),
            ]),
            MetricSnapshot::Histogram(s) => s.to_json(),
        }
    }

    pub fn from_json(json: &Json) -> Result<MetricSnapshot> {
        let kind = json
            .get("type")
            .and_then(Json::as_str)
            .context("metric snapshot: missing type tag")?;
        Ok(match kind {
            "counter" => MetricSnapshot::Counter(json_u64(json, "value")?),
            "gauge" => MetricSnapshot::Gauge {
                value: json_u64(json, "value")?,
                high_water: json_u64(json, "high_water")?,
            },
            "histogram" => {
                MetricSnapshot::Histogram(HistogramSnapshot::from_json(json)?)
            }
            other => bail!("metric snapshot: unknown type {other:?}"),
        })
    }

    /// Fleet-merge semantics, metric by metric: counters add,
    /// histograms merge bucket-wise (exact — see
    /// [`HistogramSnapshot::merge_from`]), gauges add their current
    /// values (fleet sessions = sum of shard sessions) and take the
    /// max of their high-water marks. Kind mismatches keep `self`.
    pub fn merge_from(&mut self, other: &MetricSnapshot) {
        match (self, other) {
            (MetricSnapshot::Counter(a), MetricSnapshot::Counter(b)) => {
                *a += b;
            }
            (
                MetricSnapshot::Gauge { value, high_water },
                MetricSnapshot::Gauge { value: v, high_water: hw },
            ) => {
                *value += v;
                *high_water = (*high_water).max(*hw);
            }
            (
                MetricSnapshot::Histogram(a),
                MetricSnapshot::Histogram(b),
            ) => a.merge_from(b),
            _ => {}
        }
    }
}

/// Serialize a name-sorted metric list (one [`Registry::snapshot`], or
/// several merged) as one JSON object — the `metrics` field of
/// `/metrics.json`.
pub fn metrics_to_json(metrics: &[(String, MetricSnapshot)]) -> Json {
    obj(metrics
        .iter()
        .map(|(name, snap)| (name.as_str(), snap.to_json()))
        .collect())
}

/// Parse a `metrics` JSON object back into plain-value metrics.
pub fn metrics_from_json(json: &Json)
                         -> Result<Vec<(String, MetricSnapshot)>> {
    let map = match json {
        Json::Obj(map) => map,
        _ => bail!("metrics must be a JSON object"),
    };
    let mut out = Vec::with_capacity(map.len());
    for (name, value) in map {
        let snap = MetricSnapshot::from_json(value)
            .with_context(|| format!("metric {name:?}"))?;
        out.push((name.clone(), snap));
    }
    Ok(out)
}

/// Merge one metric into a named accumulator map with
/// [`MetricSnapshot::merge_from`] semantics (the shard router's
/// fleet-wide roll-up).
pub fn merge_metric(into: &mut BTreeMap<String, MetricSnapshot>,
                    name: &str, snap: &MetricSnapshot) {
    match into.get_mut(name) {
        Some(existing) => existing.merge_from(snap),
        None => {
            into.insert(name.to_string(), snap.clone());
        }
    }
}

/// A named get-or-create metric store. Instantiable (the serve layer
/// gives each [`crate::serve::Coalescer`] its own, so parallel test
/// servers never share counters) with one process-wide
/// [`global`](Self::global) instance that kernel spans record into.
///
/// Names follow the Prometheus base-name convention:
/// `[a-z0-9_]`, `_seconds` suffix for ns-recorded duration histograms,
/// `_total` suffix for counters. The `cax_` prefix is added at
/// exposition time, not here.
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The process-wide registry (kernel spans, CLI-level metrics).
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Metric>> {
        // A metrics registry must keep serving reads even if some
        // thread panicked while holding the map.
        self.metrics
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.lock();
        if let Some(Metric::Counter(c)) = m.get(name) {
            return Arc::clone(c);
        }
        assert!(
            !m.contains_key(name),
            "obs: metric {name:?} already registered with another kind"
        );
        let c = Arc::new(Counter::default());
        m.insert(name.to_string(), Metric::Counter(Arc::clone(&c)));
        c
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.lock();
        if let Some(Metric::Gauge(g)) = m.get(name) {
            return Arc::clone(g);
        }
        assert!(
            !m.contains_key(name),
            "obs: metric {name:?} already registered with another kind"
        );
        let g = Arc::new(Gauge::default());
        m.insert(name.to_string(), Metric::Gauge(Arc::clone(&g)));
        g
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.lock();
        if let Some(Metric::Histogram(h)) = m.get(name) {
            return Arc::clone(h);
        }
        assert!(
            !m.contains_key(name),
            "obs: metric {name:?} already registered with another kind"
        );
        let h = Arc::new(Histogram::new());
        m.insert(name.to_string(), Metric::Histogram(Arc::clone(&h)));
        h
    }

    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }

    /// Name-sorted plain-value copy of every metric.
    pub fn snapshot(&self) -> Vec<(String, MetricSnapshot)> {
        self.lock()
            .iter()
            .map(|(name, metric)| {
                let snap = match metric {
                    Metric::Counter(c) => MetricSnapshot::Counter(c.get()),
                    Metric::Gauge(g) => MetricSnapshot::Gauge {
                        value: g.get(),
                        high_water: g.high_water(),
                    },
                    Metric::Histogram(h) => {
                        MetricSnapshot::Histogram(h.snapshot())
                    }
                };
                (name.clone(), snap)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_covers_u64_monotonically() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(31), 31);
        assert_eq!(bucket_index(32), 32);
        assert!(bucket_index(u64::MAX) < NUM_BUCKETS);
        let mut prev = 0;
        for v in [0u64, 1, 31, 32, 33, 63, 64, 100, 1 << 20, u64::MAX] {
            let idx = bucket_index(v);
            assert!(idx >= prev, "index must be monotone in the value");
            prev = idx;
            let (lo, hi) = bucket_bounds(idx);
            // The top bucket's upper edge saturates at u64::MAX.
            assert!(lo <= v && (v < hi || hi == u64::MAX),
                    "value {v} outside bucket [{lo}, {hi})");
        }
    }

    #[test]
    fn buckets_are_contiguous() {
        for idx in 0..NUM_BUCKETS - 1 {
            let (_, hi) = bucket_bounds(idx);
            let (lo_next, _) = bucket_bounds(idx + 1);
            assert_eq!(hi, lo_next, "gap between buckets {idx} and next");
        }
    }

    #[test]
    fn small_values_are_exact() {
        let h = Histogram::new();
        for v in [0u64, 1, 2, 3, 3, 31] {
            h.record(v);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, 6);
        assert_eq!(snap.sum, 40);
        assert_eq!(snap.min, 0);
        assert_eq!(snap.max, 31);
        assert_eq!(snap.quantile(0.0), 0.0);
        assert_eq!(snap.quantile(1.0), 31.0);
        assert_eq!(snap.cumulative_le(3), 5);
        assert_eq!(snap.cumulative_le(1000), 6);
    }

    #[test]
    fn quantiles_track_large_values_within_bucket_error() {
        let h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v * 1000);
        }
        let snap = h.snapshot();
        let p50 = snap.quantile(0.5);
        let p99 = snap.quantile(0.99);
        assert!((p50 - 5_000_500.0).abs() / 5_000_500.0 < 0.02, "{p50}");
        assert!((p99 - 9_900_010.0).abs() / 9_900_010.0 < 0.02, "{p99}");
    }

    #[test]
    fn registry_get_or_create_returns_the_same_metric() {
        let reg = Registry::new();
        let a = reg.counter("x_total");
        let b = reg.counter("x_total");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 1);
        reg.gauge("depth").set(7);
        reg.histogram("lat_seconds").record(5);
        assert_eq!(reg.snapshot().len(), 3);
    }
}
