//! `cax::obs` — the std-only process-wide observability layer.
//!
//! The paper's core claim is *measured speed*; this module is the
//! measurement substrate every surface reports through:
//!
//! - [`histogram`]: lock-free log-bucketed latency [`Histogram`]s
//!   (atomic buckets, mergeable, p50/p95/p99 queries), [`Counter`]s
//!   and high-water [`Gauge`]s in a named get-or-create [`Registry`].
//! - [`span`](mod@span): scoped RAII [`Span`] timers with static
//!   labels — one guard instruments a kernel launch; a no-op when
//!   recording is off.
//! - [`trace`]: Chrome/Perfetto trace-event capture (`--trace
//!   out.json` on the CLI) of kernel spans, scheduler ticks and batch
//!   packing.
//! - [`prometheus`]: text exposition for the serve layer's
//!   `GET /metrics`.
//! - [`log`](mod@log): the `CAX_LOG`-filtered leveled stderr logger
//!   behind `log_error!` .. `log_debug!`.
//!
//! # The contract
//!
//! Observation must never perturb what it observes. Concretely:
//!
//! 1. **Bit-identity** — spans and metrics only read clocks and bump
//!    atomics; they never touch kernel data. The serve bit-identity
//!    suite runs with recording enabled to hold this.
//! 2. **Bounded overhead** — span labels are `&'static str` (no
//!    allocation on open), recording-off spans skip the clock
//!    entirely, and `benches/serve_load.rs` asserts the instrumented
//!    Life 256x256 anchor stays within 2% of uninstrumented.
//! 3. **Bounded memory** — the histogram is a fixed 1920-bucket
//!    array; the trace buffer is capped and counts drops instead of
//!    growing.
//! 4. **Exact aggregation** — a metric snapshot serializes to JSON
//!    (`GET /metrics.json`) and parses back bit-identically
//!    ([`HistogramSnapshot`] round-trips are `PartialEq`-equal for
//!    values below 2^53), and fleet merging is raw-bucket-wise
//!    ([`HistogramSnapshot::merge_from`]): a quantile of the merged
//!    histogram equals the quantile of the union of the shards'
//!    samples at bucket resolution. The router never averages
//!    per-shard percentiles. Scraping reads snapshots only, so
//!    invariant 1 holds with fleet scraping armed.
//!
//! Trace context crosses the process boundary by id, not by buffer:
//! the router stamps each proxied request with an `X-Cax-Trace` id
//! and times it under its own Perfetto `pid`; workers adopt the id
//! into their spans ([`span::span_with_id`]) under a per-shard `pid`
//! ([`trace::set_pid`]), and `trace::write_merged` aligns the
//! captures on a shared wall-clock timebase — so one request is one
//! `args.trace` id across router → queue → batch → kernel rows.
//!
//! Metric naming: lowercase `[a-z0-9_]`, `_seconds` suffix for
//! duration histograms (recorded in ns, exposed in seconds),
//! `_total` suffix for counters; the Prometheus `cax_` prefix is
//! added at exposition time.

pub mod histogram;
pub mod log;
pub mod prometheus;
pub mod span;
pub mod trace;

pub use histogram::{
    merge_metric, metrics_from_json, metrics_to_json, Counter, Gauge,
    Histogram, HistogramSnapshot, Metric, MetricSnapshot, Registry,
};
pub use prometheus::PromWriter;
pub use span::{recording, set_recording, span, span_with_id, Span};
