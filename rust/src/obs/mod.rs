//! `cax::obs` — the std-only process-wide observability layer.
//!
//! The paper's core claim is *measured speed*; this module is the
//! measurement substrate every surface reports through:
//!
//! - [`histogram`]: lock-free log-bucketed latency [`Histogram`]s
//!   (atomic buckets, mergeable, p50/p95/p99 queries), [`Counter`]s
//!   and high-water [`Gauge`]s in a named get-or-create [`Registry`].
//! - [`span`](mod@span): scoped RAII [`Span`] timers with static
//!   labels — one guard instruments a kernel launch; a no-op when
//!   recording is off.
//! - [`trace`]: Chrome/Perfetto trace-event capture (`--trace
//!   out.json` on the CLI) of kernel spans, scheduler ticks and batch
//!   packing.
//! - [`prometheus`]: text exposition for the serve layer's
//!   `GET /metrics`.
//! - [`log`](mod@log): the `CAX_LOG`-filtered leveled stderr logger
//!   behind `log_error!` .. `log_debug!`.
//!
//! # The contract
//!
//! Observation must never perturb what it observes. Concretely:
//!
//! 1. **Bit-identity** — spans and metrics only read clocks and bump
//!    atomics; they never touch kernel data. The serve bit-identity
//!    suite runs with recording enabled to hold this.
//! 2. **Bounded overhead** — span labels are `&'static str` (no
//!    allocation on open), recording-off spans skip the clock
//!    entirely, and `benches/serve_load.rs` asserts the instrumented
//!    Life 256x256 anchor stays within 2% of uninstrumented.
//! 3. **Bounded memory** — the histogram is a fixed 1920-bucket
//!    array; the trace buffer is capped and counts drops instead of
//!    growing.
//!
//! Metric naming: lowercase `[a-z0-9_]`, `_seconds` suffix for
//! duration histograms (recorded in ns, exposed in seconds),
//! `_total` suffix for counters; the Prometheus `cax_` prefix is
//! added at exposition time.

pub mod histogram;
pub mod log;
pub mod prometheus;
pub mod span;
pub mod trace;

pub use histogram::{
    Counter, Gauge, Histogram, HistogramSnapshot, Metric, MetricSnapshot,
    Registry,
};
pub use prometheus::PromWriter;
pub use span::{recording, set_recording, span, Span};
