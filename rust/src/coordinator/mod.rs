//! Coordinator — the paper's framework layer in Rust.
//!
//! - [`registry`]: the Table-1 CA catalogue and artifact requirements.
//! - [`sim`]: classic-CA drivers over the execution paths of Fig. 3
//!   (fused / stepwise / naive baseline / native bit-packed), dispatched
//!   through the [`crate::backend`] traits.
//! - [`trainer`]: the generic fused-train-step loop + checkpoints.
//! - [`stepwise`]: host-driven BPTT (the Fig. 3-right TF-proxy baseline).
//! - [`evaluator`]: Table-2 ARC accuracy, MNIST majority vote, 3D recon.
//! - [`damage`]: the Fig. 5 amputation/regeneration protocol.
//! - [`experiments`]: one high-level driver per paper experiment.

pub mod damage;
pub mod evaluator;
pub mod experiments;
pub mod registry;
pub mod sim;
pub mod stepwise;
pub mod trainer;

pub use sim::{Path, Simulator};
pub use trainer::{train_loop, StepOutcome, TrainCfg, TrainState};
