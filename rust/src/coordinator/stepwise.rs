//! Host-driven BPTT — the Figure-3-right baseline (TF-proxy).
//!
//! The CAX fast path fuses the whole rollout + backward pass into ONE XLA
//! program (`mnist_train_step`). The baseline here reproduces the cost
//! structure the paper attributes to the per-step-dispatch implementation:
//! T forward executions (`mnist_step_fwd`) storing the trajectory on the
//! host, a loss/cotangent execution (`mnist_final_grad`), then T VJP
//! executions (`mnist_step_vjp`) accumulating parameter gradients on the
//! host, and finally a host-side Adam update. Identical math, per-step
//! dispatch + host round-trips — the measured gap isolates exactly the
//! fusion mechanism (DESIGN.md §3).

use anyhow::Result;

use crate::backend::{ProgramBackend, Value};
use crate::tensor::Tensor;

/// Host-side Adam (matches `models/common.py::adam_update`).
pub fn adam_update(params: &mut [f32], m: &mut [f32], v: &mut [f32],
                   grads: &[f32], step: i32, lr: f32) {
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let t = step as f32 + 1.0;
    let bc1 = 1.0 - b1.powf(t);
    let bc2 = 1.0 - b2.powf(t);
    for i in 0..params.len() {
        m[i] = b1 * m[i] + (1.0 - b1) * grads[i];
        v[i] = b2 * v[i] + (1.0 - b2) * grads[i] * grads[i];
        let m_hat = m[i] / bc1;
        let v_hat = v[i] / bc2;
        params[i] -= lr * m_hat / (v_hat.sqrt() + eps);
    }
}

/// Clip a gradient vector to max global norm 1.0 (matches the artifact).
pub fn clip_global_norm(grads: &mut [f32]) {
    let norm: f32 =
        grads.iter().map(|g| g * g).sum::<f32>().sqrt().max(1e-6);
    if norm > 1.0 {
        let scale = 1.0 / norm;
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
}

/// One stepwise (host-driven) MNIST training step. Returns the loss.
///
/// `init_state` builds the initial NCA state from the digit batch on the
/// host (channel 0 = digit, rest zero), mirroring `mnist_classify.init_state`.
pub fn mnist_stepwise_train_step(
    engine: &dyn ProgramBackend,
    params: &mut Tensor,
    m: &mut Tensor,
    v: &mut Tensor,
    step: i32,
    digits: &Tensor,
    labels1h: &Tensor,
    lr: f32,
    seed: u32,
) -> Result<f64> {
    let info = engine.manifest().artifact("mnist_step_fwd")?;
    let state_spec = &info.inputs[1];
    let (b, h, w, c) = (
        state_spec.shape[0], state_spec.shape[1], state_spec.shape[2],
        state_spec.shape[3],
    );
    let steps = info.meta_usize("steps").expect("mnist meta.steps");

    // Host-side init_state: digit -> channel 0.
    let mut state = Tensor::zeros(&[b, h, w, c]);
    for i in 0..b {
        for y in 0..h {
            for x in 0..w {
                state.set(&[i, y, x, 0], digits.at(&[i, y, x]));
            }
        }
    }

    // Forward: T dispatches, trajectory stored host-side.
    let mut trajectory = Vec::with_capacity(steps + 1);
    trajectory.push(state.clone());
    for t in 0..steps {
        let out = engine.execute(
            "mnist_step_fwd",
            &[
                Value::F32(params.clone()),
                Value::F32(state),
                Value::F32(digits.clone()),
                Value::U32(seed.wrapping_add(t as u32)),
            ],
        )?;
        state = out.into_iter().next().unwrap();
        trajectory.push(state.clone());
    }

    // Loss + readout cotangent.
    let out = engine.execute(
        "mnist_final_grad",
        &[
            Value::F32(trajectory[steps].clone()),
            Value::F32(digits.clone()),
            Value::F32(labels1h.clone()),
        ],
    )?;
    let loss = out[0].data()[0] as f64;
    let mut cotangent = out[1].clone();

    // Backward: T VJP dispatches, accumulating parameter grads on host.
    let n = params.numel();
    let mut grads = vec![0.0f32; n];
    for t in (0..steps).rev() {
        let out = engine.execute(
            "mnist_step_vjp",
            &[
                Value::F32(params.clone()),
                Value::F32(trajectory[t].clone()),
                Value::F32(digits.clone()),
                Value::U32(seed.wrapping_add(t as u32)),
                Value::F32(cotangent),
            ],
        )?;
        let mut it = out.into_iter();
        let dparams = it.next().unwrap();
        cotangent = it.next().unwrap();
        for (g, d) in grads.iter_mut().zip(dparams.data()) {
            *g += d;
        }
    }

    clip_global_norm(&mut grads);
    adam_update(params.data_mut(), m.data_mut(), v.data_mut(), &grads, step,
                lr);
    Ok(loss)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_reduces_quadratic() {
        let mut p = vec![5.0f32, -3.0];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        for step in 0..300 {
            let g: Vec<f32> = p.iter().map(|x| 2.0 * x).collect();
            adam_update(&mut p, &mut m, &mut v, &g, step, 0.1);
        }
        assert!(p.iter().all(|x| x.abs() < 0.5), "{p:?}");
    }

    #[test]
    fn clip_caps_norm_at_one() {
        let mut g = vec![3.0f32, 4.0];
        clip_global_norm(&mut g);
        let norm: f32 = g.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        let mut small = vec![0.3f32, 0.4];
        clip_global_norm(&mut small);
        assert_eq!(small, vec![0.3, 0.4]);
    }
}
