//! High-level experiment drivers — one function per paper experiment.
//!
//! These compose the generic [`train_loop`](crate::coordinator::trainer)
//! with each artifact's batch contract (manifest-introspected shapes) and
//! the dataset substrates. They are the single implementation shared by the
//! `cax` CLI, the `cax-tables` report generator, the examples and the
//! integration tests.

use anyhow::{Context, Result};

use crate::backend::{ProgramBackend, Value};
use crate::coordinator::trainer::{train_loop, TrainCfg, TrainState};
use crate::datasets::arc1d::{one_hot_batch, Example, Task};
use crate::datasets::mnist::{self, MnistConfig};
use crate::datasets::targets::Sprite;
use crate::metrics::History;
use crate::pool::SamplePool;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Result of one experiment training run.
pub struct TrainRun {
    pub state: TrainState,
    pub history: History,
}

impl TrainRun {
    /// Final-window mean loss (convergence check).
    pub fn final_loss(&self) -> f64 {
        let (_, last) = self.history.window_means(10);
        last
    }

    /// True iff the last-window loss improved on the first-window loss.
    pub fn improved(&self) -> bool {
        let (first, last) = self.history.window_means(10);
        last < first
    }
}

/// Render the growing-NCA target sprite at the artifact's grid size.
pub fn growing_target(engine: &dyn ProgramBackend) -> Result<Tensor> {
    let info = engine.manifest().artifact("growing_train_step")?;
    let spec = &info.inputs[5]; // target [H, W, 4]
    Ok(Sprite::Lizard.render(spec.shape[0], spec.shape[1]))
}

/// The single-seed-cell initial state from the `growing_seed` artifact.
pub fn growing_seed(engine: &dyn ProgramBackend) -> Result<Tensor> {
    let out = engine.execute("growing_seed", &[])?;
    Ok(out.into_iter().next().unwrap())
}

/// §App. B: growing NCA with the sample-pool recipe (the e2e driver).
///
/// Pool bookkeeping lives here in Layer 3: sample a batch, hand it to the
/// fused train-step artifact (rollout + BPTT + worst-of-batch reseed +
/// Adam, all in-graph), write the evolved states back.
pub fn train_growing(engine: &dyn ProgramBackend, cfg: &TrainCfg, pool_size: usize)
                     -> Result<(TrainRun, SamplePool)> {
    let info = engine.manifest().artifact("growing_train_step")?;
    let batch = info.inputs[4].shape[0];
    let target = growing_target(engine)?;
    let seed_state = growing_seed(engine)?;

    let mut state = TrainState::from_blob(engine, "growing_params")?;
    // Both closures need the pool (sample in batch_fn, write-back in the
    // observer); RefCell gives them disjoint dynamic borrows.
    let pool = std::cell::RefCell::new(SamplePool::new(pool_size,
                                                       &seed_state));
    let rng = std::cell::RefCell::new(Rng::new(cfg.seed as u64)
        .fold_in(0x6402));
    let sampled: std::cell::RefCell<Vec<usize>> =
        std::cell::RefCell::new(vec![]);

    let history = train_loop(
        engine,
        "growing_train_step",
        &mut state,
        cfg,
        |_step| {
            let (idx, states) =
                pool.borrow().sample(batch, &mut rng.borrow_mut());
            *sampled.borrow_mut() = idx;
            Ok(vec![Value::F32(states), Value::F32(target.clone())])
        },
        |outcome| {
            // extra[0] = evolved batch states (worst slot reseeded
            // in-graph); write them back to the sampled slots.
            if let Some(states) = outcome.extra.first() {
                pool.borrow_mut().write_back(&sampled.borrow(), states);
            }
            Ok(())
        },
    )?;
    Ok((TrainRun { state, history }, pool.into_inner()))
}

/// A pure-noise initial state for the diffusing NCA, matching the training
/// distribution: RGBA channels ~ U[0,1), hidden channels zero (training
/// always starts from `noisy_init`, which only noises the first 4
/// channels — full-channel noise is out of distribution).
pub fn diffusing_noise_state(engine: &dyn ProgramBackend, seed: u64) -> Result<Tensor> {
    let info = engine.manifest().artifact("diffusing_rollout")?;
    let shape = info.inputs[1].shape.clone(); // [H, W, C]
    let (h, w, c) = (shape[0], shape[1], shape[2]);
    let mut rng = Rng::new(seed).fold_in(0xD1FF);
    let mut state = Tensor::zeros(&shape);
    for y in 0..h {
        for x in 0..w {
            for ch in 0..4.min(c) {
                state.set(&[y, x, ch], rng.next_f32());
            }
        }
    }
    Ok(state)
}

/// A partially-noised diffusing-NCA state: RGBA = (1-level)*target +
/// level*noise, hidden channels zero — exactly the training distribution
/// of `noisy_init` at a chosen noise level.
pub fn diffusing_mixed_state(engine: &dyn ProgramBackend, target: &Tensor, level: f32,
                             seed: u64) -> Result<Tensor> {
    let info = engine.manifest().artifact("diffusing_rollout")?;
    let shape = info.inputs[1].shape.clone(); // [H, W, C]
    let (h, w, c) = (shape[0], shape[1], shape[2]);
    let mut rng = Rng::new(seed).fold_in(0x312D);
    let mut state = Tensor::zeros(&shape);
    for y in 0..h {
        for x in 0..w {
            for ch in 0..4.min(c) {
                let t = target.at(&[y, x, ch]);
                state.set(&[y, x, ch],
                          (1.0 - level) * t + level * rng.next_f32());
            }
        }
    }
    Ok(state)
}

/// §5.1: diffusing NCA — no pool needed (the paper's selling point).
pub fn train_diffusing(engine: &dyn ProgramBackend, cfg: &TrainCfg) -> Result<TrainRun> {
    let info = engine.manifest().artifact("diffusing_train_step")?;
    let spec = &info.inputs[4]; // target [H, W, 4]
    let target = Sprite::Lizard.render(spec.shape[0], spec.shape[1]);
    let mut state = TrainState::from_blob(engine, "diffusing_params")?;
    let history = train_loop(
        engine,
        "diffusing_train_step",
        &mut state,
        cfg,
        |_| Ok(vec![Value::F32(target.clone())]),
        |_| Ok(()),
    )?;
    Ok(TrainRun { state, history })
}

/// Goal-conditioned growing NCA (Sudhakaran et al. 2022).
pub fn train_conditional(engine: &dyn ProgramBackend, cfg: &TrainCfg) -> Result<TrainRun> {
    let info = engine.manifest().artifact("conditional_train_step")?;
    let tgt_spec = &info.inputs[4]; // [G, H, W, 4]
    let goal_spec = &info.inputs[5]; // [B, G]
    let (goals, h, w) = (tgt_spec.shape[0], tgt_spec.shape[1],
                         tgt_spec.shape[2]);
    let (b, g) = (goal_spec.shape[0], goal_spec.shape[1]);
    let sprites = [Sprite::Lizard, Sprite::Heart, Sprite::Square];
    let targets = Tensor::stack(
        &sprites.iter().take(goals).map(|s| s.render(h, w)).collect::<Vec<_>>(),
    )?;
    let mut rng = Rng::new(cfg.seed as u64).fold_in(0xC0D);
    let mut state = TrainState::from_blob(engine, "conditional_params")?;
    let history = train_loop(
        engine,
        "conditional_train_step",
        &mut state,
        cfg,
        |_| {
            let mut goals1h = Tensor::zeros(&[b, g]);
            for i in 0..b {
                goals1h.set(&[i, rng.range(0, g)], 1.0);
            }
            Ok(vec![Value::F32(targets.clone()), Value::F32(goals1h)])
        },
        |_| Ok(()),
    )?;
    Ok(TrainRun { state, history })
}

/// Digit batch + one-hot label batch at an artifact's grid size.
fn digit_batches(engine: &dyn ProgramBackend, artifact: &str, input_idx: usize,
                 n: usize, seed: u64)
                 -> Result<(Vec<Tensor>, Vec<Tensor>, usize)> {
    let info = engine.manifest().artifact(artifact)?;
    let spec = &info.inputs[input_idx]; // digits [B, H, W]
    let (b, h, w) = (spec.shape[0], spec.shape[1], spec.shape[2]);
    let cfg = MnistConfig::for_grid(h, w);
    let digits = mnist::dataset(n.max(b), &cfg, seed);
    let mut images = vec![];
    let mut labels = vec![];
    for chunk in digits.chunks(b) {
        if chunk.len() < b {
            break;
        }
        let refs: Vec<&mnist::Digit> = chunk.iter().collect();
        images.push(mnist::batch_images(&refs));
        labels.push(mnist::batch_labels(&refs));
    }
    Ok((images, labels, b))
}

/// Self-classifying MNIST (Randazzo et al. 2020) — fused train path.
pub fn train_mnist(engine: &dyn ProgramBackend, cfg: &TrainCfg) -> Result<TrainRun> {
    let (images, labels, _) =
        digit_batches(engine, "mnist_train_step", 4, cfg.steps * 4,
                      cfg.seed as u64)?;
    let mut state = TrainState::from_blob(engine, "mnist_params")?;
    let n = images.len();
    let history = train_loop(
        engine,
        "mnist_train_step",
        &mut state,
        cfg,
        |step| {
            let i = step % n;
            Ok(vec![Value::F32(images[i].clone()),
                    Value::F32(labels[i].clone())])
        },
        |_| Ok(()),
    )?;
    Ok(TrainRun { state, history })
}

/// Unsupervised VAE-NCA (Palm et al. 2021).
pub fn train_vae(engine: &dyn ProgramBackend, cfg: &TrainCfg) -> Result<TrainRun> {
    let (images, _, _) =
        digit_batches(engine, "vae_train_step", 4, cfg.steps * 4,
                      cfg.seed as u64)?;
    let mut state = TrainState::from_blob(engine, "vae_params")?;
    let n = images.len();
    let history = train_loop(
        engine,
        "vae_train_step",
        &mut state,
        cfg,
        |step| Ok(vec![Value::F32(images[step % n].clone())]),
        |_| Ok(()),
    )?;
    Ok(TrainRun { state, history })
}

/// §5.2: 3D self-autoencoding MNIST through the 1-cell bottleneck.
pub fn train_autoenc3d(engine: &dyn ProgramBackend, cfg: &TrainCfg) -> Result<TrainRun> {
    let (images, _, _) =
        digit_batches(engine, "autoenc3d_train_step", 4, cfg.steps * 4,
                      cfg.seed as u64)?;
    let mut state = TrainState::from_blob(engine, "autoenc3d_params")?;
    let n = images.len();
    let history = train_loop(
        engine,
        "autoenc3d_train_step",
        &mut state,
        cfg,
        |step| Ok(vec![Value::F32(images[step % n].clone())]),
        |_| Ok(()),
    )?;
    Ok(TrainRun { state, history })
}

/// §5.3: train the 1D-ARC NCA on one task's training split.
pub fn train_arc(engine: &dyn ProgramBackend, cfg: &TrainCfg, task: Task,
                 train_set: &[Example]) -> Result<TrainRun> {
    let info = engine.manifest().artifact("arc_train_step")?;
    let spec = &info.inputs[4]; // inputs [B, W, COLORS]
    let (b, w) = (spec.shape[0], spec.shape[1]);
    anyhow::ensure!(!train_set.is_empty(), "empty ARC train set for {task:?}");
    let mut rng = Rng::new(cfg.seed as u64).fold_in(task as u64);
    let mut state = TrainState::from_blob(engine, "arc_params")?;
    let history = train_loop(
        engine,
        "arc_train_step",
        &mut state,
        cfg,
        |_| {
            let mut ins: Vec<&[u8]> = Vec::with_capacity(b);
            let mut tgts: Vec<&[u8]> = Vec::with_capacity(b);
            for _ in 0..b {
                let e = &train_set[rng.range(0, train_set.len())];
                ins.push(&e.input);
                tgts.push(&e.target);
            }
            Ok(vec![Value::F32(one_hot_batch(&ins, w)),
                    Value::F32(one_hot_batch(&tgts, w))])
        },
        |_| Ok(()),
    )
    .with_context(|| format!("training ARC task {}", task.name()))?;
    Ok(TrainRun { state, history })
}

/// Generate a train/test split sized for the `arc_eval` artifact width.
pub fn arc_split(engine: &dyn ProgramBackend, task: Task, train: usize, test: usize,
                 seed: u64) -> Result<(Vec<Example>, Vec<Example>)> {
    let info = engine.manifest().artifact("arc_eval")?;
    let w = info.inputs[1].shape[1];
    Ok(task.dataset(w, train, test, seed))
}

/// Dispatch a training run by registry key. Returns None for classic
/// (non-trained) CAs.
pub fn train_by_key(engine: &dyn ProgramBackend, key: &str, cfg: &TrainCfg,
                    pool_size: usize) -> Result<Option<TrainRun>> {
    Ok(match key {
        "growing" => Some(train_growing(engine, cfg, pool_size)?.0),
        "conditional" => Some(train_conditional(engine, cfg)?),
        "vae" => Some(train_vae(engine, cfg)?),
        "mnist" => Some(train_mnist(engine, cfg)?),
        "diffusing" => Some(train_diffusing(engine, cfg)?),
        "autoenc3d" => Some(train_autoenc3d(engine, cfg)?),
        "arc" => {
            let (train_set, _) = arc_split(engine, Task::Denoise, 64, 0,
                                           cfg.seed as u64)?;
            Some(train_arc(engine, cfg, Task::Denoise, &train_set)?)
        }
        _ => None,
    })
}
