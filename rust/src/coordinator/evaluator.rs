//! Evaluators: 1D-ARC exact-match accuracy (Table 2) and self-classifying
//! MNIST majority-vote accuracy (Fig. 3 right's subject).

use anyhow::{bail, Result};

use crate::backend::{ProgramBackend, Value};
use crate::datasets::arc1d::{argmax_colors, one_hot_batch, Example};
use crate::datasets::mnist::Digit;
use crate::tensor::Tensor;

/// Exact-match accuracy of an ARC NCA on a test set.
///
/// The `arc_eval` artifact has a fixed batch B; the test set is run in
/// chunks (padded with repeats, padding excluded from scoring). A test case
/// counts as solved only if EVERY pixel matches the target — the paper's
/// task-success criterion (§5.3).
pub fn arc_accuracy(engine: &dyn ProgramBackend, params: &Tensor, test: &[Example])
                    -> Result<f64> {
    if test.is_empty() {
        bail!("arc_accuracy: empty test set");
    }
    let info = engine.manifest().artifact("arc_eval")?;
    let (b, w) = (info.inputs[1].shape[0], info.inputs[1].shape[1]);
    let mut solved = 0usize;

    for chunk in test.chunks(b) {
        let rows: Vec<&[u8]> = chunk
            .iter()
            .map(|e| e.input.as_slice())
            .chain(std::iter::repeat(test[0].input.as_slice()))
            .take(b)
            .collect();
        for e in chunk {
            if e.input.len() != w {
                bail!("arc_accuracy: example width {} != artifact width {w}",
                      e.input.len());
            }
        }
        let inputs = one_hot_batch(&rows, w);
        let out = engine.execute(
            "arc_eval",
            &[Value::F32(params.clone()), Value::F32(inputs)],
        )?;
        let predictions = argmax_colors(&out[0]);
        for (i, e) in chunk.iter().enumerate() {
            if predictions[i] == e.target {
                solved += 1;
            }
        }
    }
    Ok(solved as f64 / test.len() as f64)
}

/// Per-pixel agreement rate (softer diagnostic than exact match).
pub fn arc_pixel_accuracy(engine: &dyn ProgramBackend, params: &Tensor, test: &[Example])
                          -> Result<f64> {
    let info = engine.manifest().artifact("arc_eval")?;
    let (b, w) = (info.inputs[1].shape[0], info.inputs[1].shape[1]);
    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in test.chunks(b) {
        let rows: Vec<&[u8]> = chunk
            .iter()
            .map(|e| e.input.as_slice())
            .chain(std::iter::repeat(test[0].input.as_slice()))
            .take(b)
            .collect();
        let inputs = one_hot_batch(&rows, w);
        let out = engine.execute(
            "arc_eval",
            &[Value::F32(params.clone()), Value::F32(inputs)],
        )?;
        let predictions = argmax_colors(&out[0]);
        for (i, e) in chunk.iter().enumerate() {
            correct += predictions[i]
                .iter()
                .zip(&e.target)
                .filter(|(p, t)| p == t)
                .count();
            total += w;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Majority-vote classification accuracy of the self-classifying MNIST NCA:
/// each alive cell votes its argmax logit; the image's prediction is the
/// plurality vote (Randazzo et al. 2020's readout).
pub fn mnist_accuracy(engine: &dyn ProgramBackend, params: &Tensor, digits: &[&Digit],
                      seed: u32) -> Result<f64> {
    if digits.is_empty() {
        bail!("mnist_accuracy: empty evaluation set");
    }
    let info = engine.manifest().artifact("mnist_eval")?;
    let b = info.inputs[1].shape[0];
    let (h, w) = (info.inputs[1].shape[1], info.inputs[1].shape[2]);
    let mut correct = 0usize;

    for chunk in digits.chunks(b) {
        let imgs: Vec<Tensor> = chunk
            .iter()
            .map(|d| d.image.clone())
            .chain(std::iter::repeat(digits[0].image.clone()))
            .take(b)
            .collect();
        let batch = Tensor::stack(&imgs)?;
        let out = engine.execute(
            "mnist_eval",
            &[Value::F32(params.clone()), Value::F32(batch.clone()),
              Value::U32(seed)],
        )?;
        let logits = &out[0]; // [B, H, W, 10]
        let nc = logits.shape()[3];
        for (i, d) in chunk.iter().enumerate() {
            let mut votes = vec![0usize; nc];
            for y in 0..h {
                for x in 0..w {
                    if batch.at(&[i, y, x]) <= 0.1 {
                        continue; // only alive (ink) cells vote
                    }
                    let mut best = 0usize;
                    let mut best_v = f32::NEG_INFINITY;
                    for c in 0..nc {
                        let v = logits.at(&[i, y, x, c]);
                        if v > best_v {
                            best_v = v;
                            best = c;
                        }
                    }
                    votes[best] += 1;
                }
            }
            let pred = votes
                .iter()
                .enumerate()
                .max_by_key(|(_, &n)| n)
                .map(|(c, _)| c)
                .unwrap();
            if pred == d.label as usize {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / digits.len() as f64)
}

/// Reconstruction MSE of the 3D self-autoencoding NCA on a digit batch.
pub fn autoenc3d_recon_mse(engine: &dyn ProgramBackend, params: &Tensor,
                           digits: &[&Digit], seed: u32) -> Result<f64> {
    let info = engine.manifest().artifact("autoenc3d_eval")?;
    let b = info.inputs[1].shape[0];
    let mut total = 0.0f64;
    let mut count = 0usize;
    for chunk in digits.chunks(b) {
        let imgs: Vec<Tensor> = chunk
            .iter()
            .map(|d| d.image.clone())
            .chain(std::iter::repeat(digits[0].image.clone()))
            .take(b)
            .collect();
        let batch = Tensor::stack(&imgs)?;
        let out = engine.execute(
            "autoenc3d_eval",
            &[Value::F32(params.clone()), Value::F32(batch.clone()),
              Value::U32(seed)],
        )?;
        let recon = &out[0]; // [B, H, W]
        for (i, _) in chunk.iter().enumerate() {
            total += recon.index_axis0(i).mse(&batch.index_axis0(i))? as f64;
            count += 1;
        }
    }
    Ok(total / count.max(1) as f64)
}
