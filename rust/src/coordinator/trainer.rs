//! Training orchestrator: drives the fused train-step artifacts.
//!
//! The artifact owns forward rollout, BPTT, gradient clipping, the lr
//! schedule and Adam (all in-graph, DESIGN.md §4.2); this module owns
//! everything around it: parameter/optimizer buffers, batch assembly, the
//! sample pool, logging, checkpoints — the Layer-3 half of the paper's
//! App. B training loop.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::backend::{ProgramBackend, Value};
use crate::metrics::History;
use crate::tensor::Tensor;

/// Train-loop configuration.
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub steps: usize,
    pub seed: u32,
    pub log_every: usize,
    /// Where to write loss CSV / checkpoints (None = no files).
    pub out_dir: Option<PathBuf>,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg { steps: 200, seed: 0, log_every: 25, out_dir: None }
    }
}

/// Parameters + Adam state, as the artifacts expect them.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub params: Tensor,
    pub m: Tensor,
    pub v: Tensor,
    pub step: i32,
}

impl TrainState {
    /// Fresh state from an initial-parameter blob.
    pub fn from_blob(backend: &dyn ProgramBackend, blob: &str)
                     -> Result<TrainState> {
        let params = backend.load_params(blob)?;
        let n = params.numel();
        Ok(TrainState {
            params,
            m: Tensor::zeros(&[n]),
            v: Tensor::zeros(&[n]),
            step: 0,
        })
    }

    /// Save parameters as little-endian f32 (the blob format).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut bytes = Vec::with_capacity(self.params.numel() * 4);
        for &v in self.params.data() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, bytes)
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load parameters saved by [`TrainState::save`] (Adam state resets).
    pub fn load(path: &Path) -> Result<TrainState> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("checkpoint {} has non-f32 size", path.display());
        }
        let params: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let n = params.len();
        Ok(TrainState {
            params: Tensor::new(vec![n], params)?,
            m: Tensor::zeros(&[n]),
            v: Tensor::zeros(&[n]),
            step: 0,
        })
    }
}

/// One step's result handed to the observer callback.
pub struct StepOutcome<'a> {
    pub step: usize,
    pub loss: f64,
    /// Outputs beyond (params, m, v, loss) — e.g. pool write-back states.
    pub extra: &'a [Tensor],
}

/// The generic fused-train-step driver.
///
/// Artifact contract: inputs `(params, m, v, step, <batch...>, seed)`,
/// outputs `(params', m', v', loss, <extra...>)`. `batch_fn` supplies the
/// per-step batch values; `observer` sees every step's loss and extra
/// outputs (pool write-back etc.).
pub fn train_loop<B, O>(
    backend: &dyn ProgramBackend,
    artifact: &str,
    state: &mut TrainState,
    cfg: &TrainCfg,
    mut batch_fn: B,
    mut observer: O,
) -> Result<History>
where
    B: FnMut(usize) -> Result<Vec<Value>>,
    O: FnMut(StepOutcome<'_>) -> Result<()>,
{
    let info = backend.manifest().artifact(artifact)?;
    if info.outputs.len() < 4 {
        bail!("artifact {artifact} is not a train step (needs >= 4 outputs)");
    }
    let mut history = History::new(&format!("{artifact}/loss"));

    for local in 0..cfg.steps {
        let mut inputs = vec![
            Value::F32(state.params.clone()),
            Value::F32(state.m.clone()),
            Value::F32(state.v.clone()),
            Value::I32(state.step),
        ];
        inputs.extend(batch_fn(local)?);
        inputs.push(Value::U32(cfg.seed.wrapping_add(local as u32)));

        let mut out = backend
            .execute(artifact, &inputs)
            .with_context(|| format!("train step {local} of {artifact}"))?;
        let extra = out.split_off(4);
        let loss = out[3].data()[0] as f64;
        if !loss.is_finite() {
            bail!("{artifact}: loss diverged (step {local}: {loss})");
        }
        // out = [params', m', v', loss]; consume back-to-front.
        out.pop(); // loss tensor already read
        state.v = out.pop().unwrap();
        state.m = out.pop().unwrap();
        state.params = out.pop().unwrap();
        state.step += 1;

        history.push(state.step as u64, loss);
        observer(StepOutcome { step: local, loss, extra: &extra })?;

        if cfg.log_every > 0
            && (local % cfg.log_every == 0 || local + 1 == cfg.steps)
        {
            let ema = history.ema(0.1);
            crate::log_info!(
                "[{artifact}] step {:>5}  loss {loss:.6}  (ema {:.6})",
                state.step,
                ema.last().copied().unwrap_or(loss),
            );
        }
    }

    if let Some(dir) = &cfg.out_dir {
        history.write_csv(&dir.join(format!("{artifact}.loss.csv")))?;
        state.save(&dir.join(format!("{artifact}.params.bin")))?;
    }
    Ok(history)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip_bits() {
        let dir = std::env::temp_dir()
            .join(format!("cax_trainer_{}", std::process::id()));
        let path = dir.join("ck.bin");
        let state = TrainState {
            params: Tensor::new(vec![3], vec![1.5, -2.25, 0.0]).unwrap(),
            m: Tensor::zeros(&[3]),
            v: Tensor::zeros(&[3]),
            step: 7,
        };
        state.save(&path).unwrap();
        let loaded = TrainState::load(&path).unwrap();
        assert!(loaded.params.bit_eq(&state.params));
        assert_eq!(loaded.step, 0, "optimizer state resets");
        assert_eq!(loaded.m.numel(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_non_f32_sized_files() {
        let dir = std::env::temp_dir()
            .join(format!("cax_trainer_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8; 7]).unwrap();
        assert!(TrainState::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn default_cfg_is_sane() {
        let cfg = TrainCfg::default();
        assert!(cfg.steps > 0 && cfg.log_every > 0);
        assert!(cfg.out_dir.is_none());
    }
}
