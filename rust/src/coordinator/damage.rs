//! The Figure-5 damage/regeneration protocol.
//!
//! Grow (or denoise) to a developed state, amputate the lizard's tail
//! (lower-right region), roll out again, and measure RGBA recovery MSE
//! against the target over time. The paper's claim: diffusing NCAs recover
//! (wide attractor basin) while plain growing NCAs are unstable unless
//! explicitly trained to regenerate.

use anyhow::Result;

use crate::backend::{ProgramBackend, Value};
use crate::datasets::targets;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// How the amputated region is filled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DamageMode {
    /// Zero all channels (a transparent hole). For a *denoising* NCA a
    /// zeroed patch is locally indistinguishable from clean background, so
    /// this probes stability rather than regrowth.
    Zero,
    /// Re-noise the region's RGBA channels (uniform [0,1)), zero hidden —
    /// locally the training distribution at noise level 1; probes the
    /// attractor basin: the NCA must re-generate the missing anatomy from
    /// surrounding context.
    Noise,
}

/// Result of one damage trial.
#[derive(Clone, Debug)]
pub struct DamageReport {
    /// MSE to target RGBA right before damage.
    pub pre_damage_mse: f64,
    /// MSE right after damage (sanity: must exceed pre_damage).
    pub post_damage_mse: f64,
    /// MSE after the recovery rollout.
    pub recovered_mse: f64,
    /// Per-recovery-step MSE curve.
    pub curve: Vec<f64>,
}

impl DamageReport {
    /// Fraction of the damage that was healed (1 = full recovery).
    pub fn recovery_fraction(&self) -> f64 {
        let span = self.post_damage_mse - self.pre_damage_mse;
        if span <= 0.0 {
            return 0.0;
        }
        ((self.post_damage_mse - self.recovered_mse) / span).clamp(0.0, 1.0)
    }
}

fn rgba_mse(state: &Tensor, target: &Tensor) -> f64 {
    // state [H, W, C>=4], target [H, W, 4]
    let (h, w) = (target.shape()[0], target.shape()[1]);
    let mut sum = 0.0f64;
    for y in 0..h {
        for x in 0..w {
            for c in 0..4 {
                let d = state.at(&[y, x, c]) - target.at(&[y, x, c]);
                sum += (d as f64) * (d as f64);
            }
        }
    }
    sum / (h * w * 4) as f64
}

/// Run the protocol against a rollout artifact with signature
/// `(params, state[H,W,C], seed) -> (final, traj[T,H,W,C])`.
///
/// Zero every channel >= 4 (the hidden scratch channels).
fn zero_hidden(state: &mut Tensor) {
    let c = *state.shape().last().unwrap();
    if c <= 4 {
        return;
    }
    let (h, w) = (state.shape()[0], state.shape()[1]);
    for y in 0..h {
        for x in 0..w {
            for ch in 4..c {
                state.set(&[y, x, ch], 0.0);
            }
        }
    }
}

/// `develop_state` is the starting state (seed cell for growing, noisy RGBA
/// for diffusing); `develop_rounds` rollout executions are chained to reach
/// the developed state (0 = use `develop_state` as-is), `recover_rounds`
/// after the damage. Chaining far past the trained horizon is
/// out-of-distribution for the NCA — the instability that causes is itself
/// part of the Fig. 5 story, so callers choose the horizons explicitly.
///
/// `reset_hidden`: zero the hidden channels before each rollout. Growing
/// NCAs carry their alive-state there (must keep it); the diffusing NCA's
/// training distribution always starts with zero hidden channels, so its
/// denoising passes restart them — the diffusion-model "renoise and rerun"
/// analogue.
pub fn run_damage_trial(
    engine: &dyn ProgramBackend,
    rollout_artifact: &str,
    params: &Tensor,
    develop_state: Tensor,
    target: &Tensor,
    develop_rounds: usize,
    recover_rounds: usize,
    reset_hidden: bool,
    mode: DamageMode,
    seed: u32,
) -> Result<DamageReport> {
    // Develop.
    let mut state = develop_state;
    for r in 0..develop_rounds {
        if reset_hidden {
            zero_hidden(&mut state);
        }
        let mut out = engine.execute(
            rollout_artifact,
            &[Value::F32(params.clone()), Value::F32(state),
              Value::U32(seed.wrapping_add(r as u32))],
        )?;
        out.truncate(1);
        state = out.pop().unwrap();
    }
    let pre_damage_mse = rgba_mse(&state, target);

    // Amputate the tail region.
    targets::amputate_tail(&mut state);
    if mode == DamageMode::Noise {
        let shape = state.shape().to_vec();
        let (h, w) = (shape[0], shape[1]);
        let mut rng = Rng::new(seed as u64).fold_in(0xDA);
        for y in h * 3 / 5..h {
            for x in w * 3 / 5..w {
                for ch in 0..4 {
                    state.set(&[y, x, ch], rng.next_f32());
                }
            }
        }
    }
    let post_damage_mse = rgba_mse(&state, target);

    // Recover, tracking the per-rollout curve (per-step curve uses traj).
    let mut curve = Vec::new();
    for r in 0..recover_rounds {
        if reset_hidden {
            zero_hidden(&mut state);
        }
        let mut out = engine.execute(
            rollout_artifact,
            &[Value::F32(params.clone()), Value::F32(state),
              Value::U32(seed.wrapping_add(1000 + r as u32))],
        )?;
        let traj = out.pop().unwrap(); // [T, H, W, C]
        state = out.pop().unwrap();
        let t = traj.shape()[0];
        for i in 0..t {
            curve.push(rgba_mse(&traj.index_axis0(i), target));
        }
    }
    let recovered_mse = *curve.last().unwrap_or(&post_damage_mse);

    Ok(DamageReport { pre_damage_mse, post_damage_mse, recovered_mse, curve })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovery_fraction_bounds() {
        let full = DamageReport {
            pre_damage_mse: 0.01,
            post_damage_mse: 0.05,
            recovered_mse: 0.01,
            curve: vec![],
        };
        assert!((full.recovery_fraction() - 1.0).abs() < 1e-9);
        let none = DamageReport {
            pre_damage_mse: 0.01,
            post_damage_mse: 0.05,
            recovered_mse: 0.07,
            curve: vec![],
        };
        assert_eq!(none.recovery_fraction(), 0.0);
        let degenerate = DamageReport {
            pre_damage_mse: 0.05,
            post_damage_mse: 0.05,
            recovered_mse: 0.05,
            curve: vec![],
        };
        assert_eq!(degenerate.recovery_fraction(), 0.0);
    }

    #[test]
    fn zero_hidden_keeps_rgba() {
        let mut state = Tensor::full(&[3, 3, 8], 0.7);
        zero_hidden(&mut state);
        for y in 0..3 {
            for x in 0..3 {
                for c in 0..4 {
                    assert_eq!(state.at(&[y, x, c]), 0.7);
                }
                for c in 4..8 {
                    assert_eq!(state.at(&[y, x, c]), 0.0);
                }
            }
        }
        // Pure-RGBA states are untouched.
        let mut rgba = Tensor::full(&[2, 2, 4], 0.3);
        zero_hidden(&mut rgba);
        assert!(rgba.bit_eq(&Tensor::full(&[2, 2, 4], 0.3)));
    }

    #[test]
    fn rgba_mse_ignores_hidden_channels() {
        let mut state = Tensor::zeros(&[2, 2, 6]);
        let target = Tensor::zeros(&[2, 2, 4]);
        state.set(&[0, 0, 5], 9.0); // hidden channel: must not count
        assert_eq!(rgba_mse(&state, &target), 0.0);
        state.set(&[0, 0, 0], 1.0);
        assert!(rgba_mse(&state, &target) > 0.0);
    }
}
