//! CA registry — the Table-1 catalogue as a first-class runtime object.
//!
//! Each entry names the CA family, its paper row (type/dimensions), and the
//! artifacts it needs. `cax list` prints it; the table1_coverage test
//! asserts every entry's artifacts exist in the manifest.

use crate::runtime::Manifest;

/// CA class, mirroring paper Table 1's "Type" column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CaType {
    Discrete,
    Continuous,
    Neural,
}

impl CaType {
    pub fn name(&self) -> &'static str {
        match self {
            CaType::Discrete => "Discrete",
            CaType::Continuous => "Continuous",
            CaType::Neural => "Neural",
        }
    }
}

/// One Table-1 row.
#[derive(Clone, Debug)]
pub struct CaEntry {
    /// Registry key (CLI name).
    pub key: &'static str,
    /// Paper Table 1 row label.
    pub label: &'static str,
    pub ca_type: CaType,
    pub dimensions: &'static str,
    /// Artifacts this CA needs at runtime.
    pub artifacts: &'static [&'static str],
    /// Initial-parameter blob, for neural CAs.
    pub params_blob: Option<&'static str>,
}

/// The full Table 1 (paper order), including the three novel experiments.
pub fn table1() -> Vec<CaEntry> {
    vec![
        CaEntry {
            key: "eca",
            label: "Elementary Cellular Automata",
            ca_type: CaType::Discrete,
            dimensions: "1D",
            artifacts: &["eca_step", "eca_rollout", "eca_traj"],
            params_blob: None,
        },
        CaEntry {
            key: "life",
            label: "Conway's Game of Life",
            ca_type: CaType::Discrete,
            dimensions: "2D",
            artifacts: &["life_step", "life_rollout", "life_traj"],
            params_blob: None,
        },
        CaEntry {
            key: "lenia",
            label: "Lenia",
            ca_type: CaType::Continuous,
            dimensions: "ND",
            artifacts: &["lenia_step", "lenia_rollout", "lenia_traj"],
            params_blob: None,
        },
        CaEntry {
            key: "growing",
            label: "Growing Neural Cellular Automata",
            ca_type: CaType::Neural,
            dimensions: "2D",
            artifacts: &["growing_train_step", "growing_rollout",
                         "growing_seed"],
            params_blob: Some("growing_params"),
        },
        CaEntry {
            key: "conditional",
            label: "Growing Conditional Neural Cellular Automata",
            ca_type: CaType::Neural,
            dimensions: "2D",
            artifacts: &["conditional_train_step", "conditional_grow"],
            params_blob: Some("conditional_params"),
        },
        CaEntry {
            key: "vae",
            label: "Growing Unsupervised Neural Cellular Automata",
            ca_type: CaType::Neural,
            dimensions: "2D",
            artifacts: &["vae_train_step", "vae_reconstruct"],
            params_blob: Some("vae_params"),
        },
        CaEntry {
            key: "mnist",
            label: "Self-classifying MNIST Digits",
            ca_type: CaType::Neural,
            dimensions: "2D",
            artifacts: &["mnist_train_step", "mnist_eval", "mnist_step_fwd",
                         "mnist_step_vjp", "mnist_final_grad"],
            params_blob: Some("mnist_params"),
        },
        CaEntry {
            key: "diffusing",
            label: "Diffusing Neural Cellular Automata",
            ca_type: CaType::Neural,
            dimensions: "2D",
            artifacts: &["diffusing_train_step", "diffusing_rollout"],
            params_blob: Some("diffusing_params"),
        },
        CaEntry {
            key: "autoenc3d",
            label: "Self-autoencoding MNIST Digits",
            ca_type: CaType::Neural,
            dimensions: "3D",
            artifacts: &["autoenc3d_train_step", "autoenc3d_eval"],
            params_blob: Some("autoenc3d_params"),
        },
        CaEntry {
            key: "arc",
            label: "1D-ARC Neural Cellular Automata",
            ca_type: CaType::Neural,
            dimensions: "1D",
            artifacts: &["arc_train_step", "arc_eval", "arc_traj"],
            params_blob: Some("arc_params"),
        },
    ]
}

/// Look up a registry entry by CLI key.
pub fn find(key: &str) -> Option<CaEntry> {
    table1().into_iter().find(|e| e.key == key)
}

/// Names of registry artifacts missing from a manifest (empty = complete).
pub fn missing_artifacts(manifest: &Manifest) -> Vec<String> {
    let mut missing = vec![];
    for entry in table1() {
        for &art in entry.artifacts {
            if !manifest.artifacts.contains_key(art) {
                missing.push(format!("{}:{}", entry.key, art));
            }
        }
        if let Some(blob) = entry.params_blob {
            if !manifest.blobs.contains_key(blob) {
                missing.push(format!("{}:blob:{}", entry.key, blob));
            }
        }
    }
    missing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_rows_like_the_paper() {
        assert_eq!(table1().len(), 10);
    }

    #[test]
    fn keys_unique() {
        let mut keys: Vec<_> = table1().iter().map(|e| e.key).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 10);
    }

    #[test]
    fn type_distribution_matches_table1() {
        let t = table1();
        let count = |ty: CaType| t.iter().filter(|e| e.ca_type == ty).count();
        assert_eq!(count(CaType::Discrete), 2);
        assert_eq!(count(CaType::Continuous), 1);
        assert_eq!(count(CaType::Neural), 7);
    }

    #[test]
    fn neural_cas_have_param_blobs() {
        for e in table1() {
            assert_eq!(
                e.params_blob.is_some(),
                e.ca_type == CaType::Neural,
                "{}", e.key
            );
            assert!(!e.artifacts.is_empty(), "{}", e.key);
        }
    }

    #[test]
    fn find_by_key() {
        assert_eq!(find("arc").unwrap().dimensions, "1D");
        assert_eq!(find("autoenc3d").unwrap().dimensions, "3D");
        assert!(find("nope").is_none());
    }
}
