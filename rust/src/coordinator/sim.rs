//! Simulation engine: drives the classic-CA artifacts (fused and stepwise)
//! and the naive Rust baselines behind one interface — the comparison
//! surface of Figure 3.

use anyhow::Result;

use crate::automata::{EcaSim, LeniaSim, LifeSim, WolframRule};
use crate::automata::lenia::LeniaParams;
use crate::runtime::{Engine, Value};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Which execution path a classic-CA run takes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Path {
    /// Fused `lax.scan` rollout in one XLA program (the CAX fast path).
    Fused,
    /// One XLA execution per step, host round-trip between steps
    /// (per-step-dispatch cost structure).
    Stepwise,
    /// Naive per-cell Rust loops (the CellPyLib-role baseline).
    Naive,
}

impl Path {
    pub fn name(&self) -> &'static str {
        match self {
            Path::Fused => "cax-fused",
            Path::Stepwise => "xla-stepwise",
            Path::Naive => "naive-baseline",
        }
    }
}

/// Classic-CA simulation driver over an [`Engine`].
pub struct Simulator<'e> {
    pub engine: &'e Engine,
}

impl<'e> Simulator<'e> {
    pub fn new(engine: &'e Engine) -> Simulator<'e> {
        Simulator { engine }
    }

    /// Random {0,1} state matching an artifact's `state` input shape.
    pub fn random_state(&self, artifact: &str, rng: &mut Rng) -> Result<Tensor> {
        let info = self.engine.manifest().artifact(artifact)?;
        let spec = &info.inputs[0];
        let data = rng.binary_vec(spec.numel(), 0.5);
        Tensor::new(spec.shape.clone(), data)
    }

    // ------------------------------------------------------------ ECA

    /// Run ECA for the artifact-configured number of steps on `path`.
    /// `steps` only applies to Stepwise/Naive (Fused bakes T in-graph).
    pub fn run_eca(&self, path: Path, state: &Tensor, rule: WolframRule,
                   steps: usize) -> Result<Tensor> {
        self.run_eca_named("eca_step", "eca_rollout", path, state, rule,
                           steps)
    }

    /// As [`run_eca`](Self::run_eca) with explicit artifact names (the
    /// bench harness uses the `*_bench`-scale variants).
    pub fn run_eca_named(&self, step_art: &str, rollout_art: &str,
                         path: Path, state: &Tensor, rule: WolframRule,
                         steps: usize) -> Result<Tensor> {
        let rule_t =
            Tensor::new(vec![8], rule.table_f32().to_vec()).unwrap();
        match path {
            Path::Fused => {
                let out = self.engine.execute(
                    rollout_art,
                    &[Value::F32(state.clone()), Value::F32(rule_t)],
                )?;
                Ok(out.into_iter().next().unwrap())
            }
            Path::Stepwise => {
                let mut cur = state.clone();
                for _ in 0..steps {
                    let out = self.engine.execute(
                        step_art,
                        &[Value::F32(cur), Value::F32(rule_t.clone())],
                    )?;
                    cur = out.into_iter().next().unwrap();
                }
                Ok(cur)
            }
            Path::Naive => {
                let mut sim = EcaSim::from_tensor(rule, state);
                sim.run(steps);
                Ok(sim.to_tensor())
            }
        }
    }

    /// ECA trajectory [T, B, W] via the fused traj artifact.
    pub fn eca_traj(&self, state: &Tensor, rule: WolframRule)
                    -> Result<(Tensor, Tensor)> {
        let rule_t = Tensor::new(vec![8], rule.table_f32().to_vec()).unwrap();
        let mut out = self.engine.execute(
            "eca_traj", &[Value::F32(state.clone()), Value::F32(rule_t)],
        )?;
        let traj = out.pop().unwrap();
        let final_state = out.pop().unwrap();
        Ok((final_state, traj))
    }

    // ------------------------------------------------------------ Life

    pub fn run_life(&self, path: Path, state: &Tensor, steps: usize)
                    -> Result<Tensor> {
        self.run_life_named("life_step", "life_rollout", path, state, steps)
    }

    /// As [`run_life`](Self::run_life) with explicit artifact names.
    pub fn run_life_named(&self, step_art: &str, rollout_art: &str,
                          path: Path, state: &Tensor, steps: usize)
                          -> Result<Tensor> {
        match path {
            Path::Fused => {
                let out = self
                    .engine
                    .execute(rollout_art, &[Value::F32(state.clone())])?;
                Ok(out.into_iter().next().unwrap())
            }
            Path::Stepwise => {
                let mut cur = state.clone();
                for _ in 0..steps {
                    let out =
                        self.engine.execute(step_art, &[Value::F32(cur)])?;
                    cur = out.into_iter().next().unwrap();
                }
                Ok(cur)
            }
            Path::Naive => {
                let mut sim = LifeSim::from_tensor(state);
                sim.run(steps);
                Ok(sim.to_tensor())
            }
        }
    }

    pub fn life_traj(&self, state: &Tensor) -> Result<(Tensor, Tensor)> {
        let mut out =
            self.engine.execute("life_traj", &[Value::F32(state.clone())])?;
        let traj = out.pop().unwrap();
        let final_state = out.pop().unwrap();
        Ok((final_state, traj))
    }

    // ------------------------------------------------------------ Lenia

    /// The FFT'd ring kernel the Lenia artifacts expect, from the manifest
    /// blob.
    pub fn lenia_kernel(&self) -> Result<Tensor> {
        let info = self.engine.manifest().artifact("lenia_step")?;
        let spec = &info.inputs[1];
        let data = self.engine.manifest().load_blob("lenia_kfft")?;
        Tensor::new(spec.shape.clone(), data)
    }

    pub fn run_lenia(&self, path: Path, state: &Tensor, steps: usize)
                     -> Result<Tensor> {
        match path {
            Path::Fused => {
                let kfft = self.lenia_kernel()?;
                let out = self.engine.execute(
                    "lenia_rollout",
                    &[Value::F32(state.clone()), Value::F32(kfft)],
                )?;
                Ok(out.into_iter().next().unwrap())
            }
            Path::Stepwise => {
                let kfft = self.lenia_kernel()?;
                let mut cur = state.clone();
                for _ in 0..steps {
                    let out = self.engine.execute(
                        "lenia_step",
                        &[Value::F32(cur), Value::F32(kfft.clone())],
                    )?;
                    cur = out.into_iter().next().unwrap();
                }
                Ok(cur)
            }
            Path::Naive => {
                let info = self.engine.manifest().artifact("lenia_step")?;
                let params = LeniaParams {
                    radius: info.meta_usize("radius").unwrap_or(10),
                    mu: info.meta_f64("mu").unwrap_or(0.15) as f32,
                    sigma: info.meta_f64("sigma").unwrap_or(0.017) as f32,
                    dt: info.meta_f64("dt").unwrap_or(0.1) as f32,
                };
                // Naive sim is single-board; run each batch element.
                let b = state.shape()[0];
                let mut outs = Vec::with_capacity(b);
                for i in 0..b {
                    let mut sim =
                        LeniaSim::new(params, state.index_axis0(i));
                    sim.run(steps);
                    outs.push(sim.state().clone());
                }
                Tensor::stack(&outs)
            }
        }
    }

    pub fn lenia_traj(&self, state: &Tensor) -> Result<(Tensor, Tensor)> {
        let kfft = self.lenia_kernel()?;
        let mut out = self.engine.execute(
            "lenia_traj", &[Value::F32(state.clone()), Value::F32(kfft)],
        )?;
        let traj = out.pop().unwrap();
        let final_state = out.pop().unwrap();
        Ok((final_state, traj))
    }

    /// Cell updates per full run for an artifact (throughput denominators).
    pub fn cell_updates(&self, artifact: &str, steps: usize) -> Result<f64> {
        let info = self.engine.manifest().artifact(artifact)?;
        let cells: usize = info.inputs[0].numel();
        Ok(cells as f64 * steps as f64)
    }
}
