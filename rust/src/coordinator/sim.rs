//! Simulation engine: drives the classic CAs over every execution path
//! — the comparison surface of Figure 3, now dispatching through the
//! pluggable backend layer.
//!
//! Paths:
//! - [`Path::Fused`]: whole rollout as ONE XLA program (`pjrt` feature).
//! - [`Path::Stepwise`]: one XLA execution per step, host round-trips.
//! - [`Path::Naive`]: per-cell scalar Rust loops (the CellPyLib role).
//! - [`Path::Native`]: the multi-threaded bit-packed/tiled
//!   [`NativeBackend`] — the hermetic fast path; no artifacts needed.

use anyhow::{anyhow, Result};

use crate::automata::lenia::{LeniaParams, LeniaWorld};
use crate::automata::{EcaSim, LeniaSim, LifeSim, WolframRule};
use crate::backend::{Backend, CaProgram, NativeBackend, ProgramBackend,
                     Value};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Which execution path a classic-CA run takes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Path {
    /// Fused `lax.scan` rollout in one XLA program (the CAX fast path).
    Fused,
    /// One XLA execution per step, host round-trip between steps
    /// (per-step-dispatch cost structure).
    Stepwise,
    /// Naive per-cell Rust loops (the CellPyLib-role baseline).
    Naive,
    /// Bit-packed / cache-tiled multi-threaded native backend.
    Native,
}

impl Path {
    pub fn name(&self) -> &'static str {
        match self {
            Path::Fused => "cax-fused",
            Path::Stepwise => "xla-stepwise",
            Path::Naive => "naive-baseline",
            Path::Native => "native-bitpacked",
        }
    }

    /// Parse a CLI `--path` value.
    pub fn parse(text: &str) -> Result<Path> {
        match text {
            "fused" => Ok(Path::Fused),
            "stepwise" => Ok(Path::Stepwise),
            "naive" => Ok(Path::Naive),
            "native" => Ok(Path::Native),
            other => Err(anyhow!(
                "unknown path {other:?} (want fused|stepwise|naive|native)"
            )),
        }
    }

    /// Whether this path needs an artifact-backed program backend.
    pub fn needs_programs(&self) -> bool {
        matches!(self, Path::Fused | Path::Stepwise)
    }
}

/// Classic-CA simulation driver over the backend layer.
///
/// Holds an optional [`ProgramBackend`] (the XLA paths and manifest
/// introspection need one) plus an always-present [`NativeBackend`].
pub struct Simulator<'e> {
    program: Option<&'e dyn ProgramBackend>,
    native: NativeBackend,
}

impl<'e> Simulator<'e> {
    /// Simulator over an artifact-backed program backend (all paths).
    pub fn new(program: &'e dyn ProgramBackend) -> Simulator<'e> {
        Simulator { program: Some(program), native: NativeBackend::new() }
    }

    /// Simulator with only the native + naive paths (no artifacts).
    pub fn native_only() -> Simulator<'static> {
        Simulator { program: None, native: NativeBackend::new() }
    }

    /// The native backend (e.g. to query its worker count).
    pub fn native(&self) -> &NativeBackend {
        &self.native
    }

    fn program(&self) -> Result<&'e dyn ProgramBackend> {
        self.program.ok_or_else(|| {
            anyhow!(
                "this Simulator has no program backend: the fused/stepwise \
                 XLA paths need artifacts (build with --features pjrt and \
                 run `make artifacts`); use --path native instead"
            )
        })
    }

    /// Random {0,1} state matching an artifact's `state` input shape.
    pub fn random_state(&self, artifact: &str, rng: &mut Rng)
                        -> Result<Tensor> {
        let program = self.program()?;
        let info = program.manifest().artifact(artifact)?;
        let spec = &info.inputs[0];
        let data = rng.binary_vec(spec.numel(), 0.5);
        Tensor::new(spec.shape.clone(), data)
    }

    /// Random {0,1} state of an explicit shape (artifact-free paths).
    pub fn random_binary_state(shape: &[usize], rng: &mut Rng) -> Tensor {
        let numel = shape.iter().product();
        Tensor::new(shape.to_vec(), rng.binary_vec(numel, 0.5)).unwrap()
    }

    // ------------------------------------------------------------ ECA

    /// Run ECA for `steps` on `path` (`steps` is baked in-graph for
    /// Fused; it applies to the other paths).
    pub fn run_eca(&self, path: Path, state: &Tensor, rule: WolframRule,
                   steps: usize) -> Result<Tensor> {
        self.run_eca_named("eca_step", "eca_rollout", path, state, rule,
                           steps)
    }

    /// As [`run_eca`](Self::run_eca) with explicit artifact names (the
    /// bench harness uses the `*_bench`-scale variants).
    pub fn run_eca_named(&self, step_art: &str, rollout_art: &str,
                         path: Path, state: &Tensor, rule: WolframRule,
                         steps: usize) -> Result<Tensor> {
        match path {
            Path::Fused => {
                let rule_t =
                    Tensor::new(vec![8], rule.table_f32().to_vec()).unwrap();
                let out = self.program()?.execute(
                    rollout_art,
                    &[Value::F32(state.clone()), Value::F32(rule_t)],
                )?;
                Ok(out.into_iter().next().unwrap())
            }
            Path::Stepwise => {
                let rule_t =
                    Tensor::new(vec![8], rule.table_f32().to_vec()).unwrap();
                let program = self.program()?;
                let mut cur = state.clone();
                for _ in 0..steps {
                    let out = program.execute(
                        step_art,
                        &[Value::F32(cur), Value::F32(rule_t.clone())],
                    )?;
                    cur = out.into_iter().next().unwrap();
                }
                Ok(cur)
            }
            Path::Naive => {
                let mut sim = EcaSim::from_tensor(rule, state);
                sim.run(steps);
                Ok(sim.to_tensor())
            }
            Path::Native => {
                self.native.rollout(&CaProgram::Eca { rule }, state, steps)
            }
        }
    }

    /// ECA trajectory [T, B, W] via the fused traj artifact.
    pub fn eca_traj(&self, state: &Tensor, rule: WolframRule)
                    -> Result<(Tensor, Tensor)> {
        let rule_t = Tensor::new(vec![8], rule.table_f32().to_vec()).unwrap();
        let mut out = self.program()?.execute(
            "eca_traj", &[Value::F32(state.clone()), Value::F32(rule_t)],
        )?;
        let traj = out.pop().unwrap();
        let final_state = out.pop().unwrap();
        Ok((final_state, traj))
    }

    // ------------------------------------------------------------ Life

    pub fn run_life(&self, path: Path, state: &Tensor, steps: usize)
                    -> Result<Tensor> {
        self.run_life_named("life_step", "life_rollout", path, state, steps)
    }

    /// As [`run_life`](Self::run_life) with explicit artifact names.
    pub fn run_life_named(&self, step_art: &str, rollout_art: &str,
                          path: Path, state: &Tensor, steps: usize)
                          -> Result<Tensor> {
        match path {
            Path::Fused => {
                let out = self
                    .program()?
                    .execute(rollout_art, &[Value::F32(state.clone())])?;
                Ok(out.into_iter().next().unwrap())
            }
            Path::Stepwise => {
                let program = self.program()?;
                let mut cur = state.clone();
                for _ in 0..steps {
                    let out =
                        program.execute(step_art, &[Value::F32(cur)])?;
                    cur = out.into_iter().next().unwrap();
                }
                Ok(cur)
            }
            Path::Naive => {
                let mut sim = LifeSim::from_tensor(state);
                sim.run(steps);
                Ok(sim.to_tensor())
            }
            Path::Native => {
                self.native.rollout(&CaProgram::Life, state, steps)
            }
        }
    }

    pub fn life_traj(&self, state: &Tensor) -> Result<(Tensor, Tensor)> {
        let mut out = self
            .program()?
            .execute("life_traj", &[Value::F32(state.clone())])?;
        let traj = out.pop().unwrap();
        let final_state = out.pop().unwrap();
        Ok((final_state, traj))
    }

    // ------------------------------------------------------------ Lenia

    /// The FFT'd ring kernel the Lenia artifacts expect, from the manifest
    /// blob.
    pub fn lenia_kernel(&self) -> Result<Tensor> {
        crate::backend::lenia_kernel_fft(self.program()?)
    }

    /// Lenia world parameters: manifest metadata when a program backend
    /// is attached, the paper defaults otherwise.
    pub fn lenia_params(&self) -> LeniaParams {
        let defaults = LeniaParams::default();
        let Some(program) = self.program else {
            return defaults;
        };
        let Ok(info) = program.manifest().artifact("lenia_step") else {
            return defaults;
        };
        LeniaParams {
            radius: info.meta_usize("radius").unwrap_or(defaults.radius),
            mu: info.meta_f64("mu").unwrap_or(defaults.mu as f64) as f32,
            sigma: info.meta_f64("sigma").unwrap_or(defaults.sigma as f64)
                as f32,
            dt: info.meta_f64("dt").unwrap_or(defaults.dt as f64) as f32,
        }
    }

    pub fn run_lenia(&self, path: Path, state: &Tensor, steps: usize)
                     -> Result<Tensor> {
        self.run_lenia_params(path, self.lenia_params(), state, steps)
    }

    /// As [`run_lenia`](Self::run_lenia) with explicit world parameters.
    /// `params` drives the naive/native paths; the XLA paths always run
    /// the kernel baked into their artifacts.
    pub fn run_lenia_params(&self, path: Path, params: LeniaParams,
                            state: &Tensor, steps: usize) -> Result<Tensor> {
        match path {
            Path::Fused => {
                let kfft = self.lenia_kernel()?;
                let out = self.program()?.execute(
                    "lenia_rollout",
                    &[Value::F32(state.clone()), Value::F32(kfft)],
                )?;
                Ok(out.into_iter().next().unwrap())
            }
            Path::Stepwise => {
                let kfft = self.lenia_kernel()?;
                let program = self.program()?;
                let mut cur = state.clone();
                for _ in 0..steps {
                    let out = program.execute(
                        "lenia_step",
                        &[Value::F32(cur), Value::F32(kfft.clone())],
                    )?;
                    cur = out.into_iter().next().unwrap();
                }
                Ok(cur)
            }
            Path::Naive => {
                // Same wrap-index precondition the native backend checks.
                crate::backend::validate_state(
                    &CaProgram::Lenia { params }, state,
                )?;
                // Naive sim is single-board; run each batch element.
                let b = state.shape()[0];
                let mut outs = Vec::with_capacity(b);
                for i in 0..b {
                    let mut sim =
                        LeniaSim::new(params, state.index_axis0(i));
                    sim.run(steps);
                    outs.push(sim.state().clone());
                }
                Tensor::stack(&outs)
            }
            Path::Native => {
                self.native
                    .rollout(&CaProgram::Lenia { params }, state, steps)
            }
        }
    }

    /// Which native kernel path [`Path::Native`] Lenia takes for this
    /// radius and board — surfaced so the CLI/benches can report it.
    pub fn lenia_native_path(params: LeniaParams, h: usize, w: usize)
        -> &'static str {
        crate::backend::native::lenia::select_path(params.radius, h, w)
            .name()
    }

    /// Which step path (`dense` / `sparse` / `hashlife`) the native
    /// backend's activity cost model picks for one launch of `prog` on
    /// an unbatched board of `shape` advancing `steps` — the stepping
    /// analogue of [`lenia_native_path`](Self::lenia_native_path),
    /// surfaced the same way through `cax sim` and serve session
    /// status.
    pub fn native_step_path(prog: &CaProgram, shape: &[usize],
                            steps: usize) -> &'static str {
        crate::backend::native::activity::select_step_path(prog, shape,
                                                           steps)
            .name()
    }

    /// Generalized multi-channel / multi-kernel Lenia on `[B, C, H, W]`
    /// states. `Native` runs the spectral path; `Naive` runs the scalar
    /// reference oracle; the XLA paths have no artifact for worlds.
    pub fn run_lenia_world(&self, path: Path, world: &LeniaWorld,
                           state: &Tensor, steps: usize) -> Result<Tensor> {
        match path {
            Path::Native => self.native.rollout(
                &CaProgram::LeniaMulti(world.clone()),
                state,
                steps,
            ),
            Path::Naive => {
                let prog = CaProgram::LeniaMulti(world.clone());
                crate::backend::validate_state(&prog, state)?;
                let shape = state.shape().to_vec();
                let (h, w) = (shape[2], shape[3]);
                let chw: usize = shape[1..].iter().product();
                let mut data = state.data().to_vec();
                for board in data.chunks_mut(chw) {
                    world.rollout_naive(board, h, w, steps);
                }
                Tensor::new(shape, data)
            }
            Path::Fused | Path::Stepwise => Err(anyhow!(
                "multi-kernel Lenia worlds run on --path native (spectral) \
                 or --path naive (scalar reference); no XLA artifact \
                 exists for them"
            )),
        }
    }

    pub fn lenia_traj(&self, state: &Tensor) -> Result<(Tensor, Tensor)> {
        let kfft = self.lenia_kernel()?;
        let mut out = self.program()?.execute(
            "lenia_traj", &[Value::F32(state.clone()), Value::F32(kfft)],
        )?;
        let traj = out.pop().unwrap();
        let final_state = out.pop().unwrap();
        Ok((final_state, traj))
    }

    /// Cell updates per full run for an artifact (throughput denominators).
    pub fn cell_updates(&self, artifact: &str, steps: usize) -> Result<f64> {
        let info = self.program()?.manifest().artifact(artifact)?;
        let cells: usize = info.inputs[0].numel();
        Ok(cells as f64 * steps as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_names_and_parse() {
        for (text, path) in [("fused", Path::Fused),
                             ("stepwise", Path::Stepwise),
                             ("naive", Path::Naive),
                             ("native", Path::Native)] {
            assert_eq!(Path::parse(text).unwrap(), path);
        }
        assert!(Path::parse("warp").is_err());
        assert!(Path::Fused.needs_programs());
        assert!(!Path::Native.needs_programs());
        assert_eq!(Path::Native.name(), "native-bitpacked");
    }

    #[test]
    fn native_only_simulator_runs_all_classic_cas() {
        let sim = Simulator::native_only();
        let mut rng = Rng::new(5);
        let eca = Simulator::random_binary_state(&[2, 70], &mut rng);
        let out = sim
            .run_eca(Path::Native, &eca, WolframRule::new(30), 8)
            .unwrap();
        assert_eq!(out.shape(), &[2, 70]);

        let life = Simulator::random_binary_state(&[2, 12, 12], &mut rng);
        let out = sim.run_life(Path::Native, &life, 4).unwrap();
        assert_eq!(out.shape(), &[2, 12, 12]);

        let lenia = Simulator::random_binary_state(&[1, 32, 32], &mut rng);
        let out = sim.run_lenia(Path::Native, &lenia, 2).unwrap();
        assert_eq!(out.shape(), &[1, 32, 32]);
    }

    #[test]
    fn native_only_simulator_refuses_xla_paths() {
        let sim = Simulator::native_only();
        let mut rng = Rng::new(6);
        let state = Simulator::random_binary_state(&[1, 16], &mut rng);
        let err = sim
            .run_eca(Path::Fused, &state, WolframRule::new(30), 4)
            .unwrap_err();
        assert!(format!("{err:#}").contains("native"));
        assert!(sim.cell_updates("eca_rollout", 4).is_err());
    }

    #[test]
    fn native_path_matches_naive_paths() {
        let sim = Simulator::native_only();
        let mut rng = Rng::new(7);
        let state = Simulator::random_binary_state(&[3, 65], &mut rng);
        let rule = WolframRule::new(110);
        let naive = sim.run_eca(Path::Naive, &state, rule, 9).unwrap();
        let native = sim.run_eca(Path::Native, &state, rule, 9).unwrap();
        assert!(naive.bit_eq(&native));
    }

    #[test]
    fn lenia_world_native_matches_naive_reference() {
        let sim = Simulator::native_only();
        let world = LeniaWorld::demo(2, 4);
        let mut rng = Rng::new(0x77D);
        let state = Tensor::new(
            vec![2, world.channels, 24, 20],
            rng.vec_f32(2 * world.channels * 24 * 20),
        )
        .unwrap();
        let a = sim
            .run_lenia_world(Path::Native, &world, &state, 4)
            .unwrap();
        let b = sim
            .run_lenia_world(Path::Naive, &world, &state, 4)
            .unwrap();
        assert_eq!(a.shape(), state.shape());
        let diff = a.max_abs_diff(&b).unwrap();
        assert!(diff <= 1e-4, "world paths drifted {diff}");
        let err = sim
            .run_lenia_world(Path::Fused, &world, &state, 1)
            .unwrap_err();
        assert!(format!("{err:#}").contains("native"));
    }

    #[test]
    fn lenia_custom_radius_spectral_path_matches_naive() {
        // radius 32 on 64x64 sits above the crossover: Path::Native
        // dispatches to the spectral kernel; the naive oracle stays on
        // direct taps. Two steps keep the chaotic growth regime from
        // amplifying the f32-vs-f64 convolution noise (see
        // tests/native_fft_props.rs for the long-horizon contract).
        let sim = Simulator::native_only();
        let params = LeniaParams { radius: 32, ..Default::default() };
        let mut rng = Rng::new(0xFF2);
        let state = Simulator::random_binary_state(&[1, 64, 64], &mut rng);
        let a = sim
            .run_lenia_params(Path::Naive, params, &state, 2)
            .unwrap();
        let b = sim
            .run_lenia_params(Path::Native, params, &state, 2)
            .unwrap();
        let diff = a.max_abs_diff(&b).unwrap();
        assert!(diff <= 1e-4, "adaptive spectral drifted {diff}");
    }

    #[test]
    fn lenia_native_path_reports_crossover() {
        let small = LeniaParams { radius: 5, ..Default::default() };
        let big = LeniaParams { radius: 48, ..Default::default() };
        assert_eq!(Simulator::lenia_native_path(small, 128, 128),
                   "sparse-tap");
        assert_eq!(Simulator::lenia_native_path(big, 128, 128), "fft");
    }

    #[test]
    fn native_step_path_reports_the_cost_model() {
        use crate::automata::WolframRule;
        // Geometry gates (power-of-two, size, horizon) are pinned in
        // activity's own unit tests; here we only check the surface
        // wiring under the ambient (default-on) dispatch.
        let life = Simulator::native_step_path(&CaProgram::Life,
                                               &[256, 256], 8);
        assert!(life == "sparse" || life == "dense");
        let eca = Simulator::native_step_path(
            &CaProgram::Eca { rule: WolframRule::new(30) }, &[1024], 8);
        assert!(eca == "sparse" || eca == "dense");
    }

    #[test]
    fn lenia_params_default_without_manifest() {
        let sim = Simulator::native_only();
        let p = sim.lenia_params();
        let d = LeniaParams::default();
        assert_eq!(p.radius, d.radius);
        assert_eq!(p.mu, d.mu);
    }
}
