//! Sample pool — the paper's §3.2.2 training utility, owned by Layer 3.
//!
//! The growing-NCA recipe (Mordvintsev et al. 2020, App. B notebook) keeps a
//! pool of intermediate states, samples a batch each step, trains on it, and
//! writes the post-rollout states back. Worst-of-batch reseeding happens
//! *in-graph* inside the train-step artifact; the pool's job here is exact
//! bookkeeping: sampling without replacement, write-back, and staleness
//! accounting.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Fixed-capacity state pool over tensors of identical shape.
#[derive(Clone, Debug)]
pub struct SamplePool {
    states: Tensor,       // [P, ...state shape]
    ages: Vec<u64>,       // training steps since last write-back
    writes: u64,
}

impl SamplePool {
    /// Initialize every slot with (a copy of) `seed_state`.
    pub fn new(capacity: usize, seed_state: &Tensor) -> SamplePool {
        assert!(capacity > 0, "pool capacity must be positive");
        let parts: Vec<Tensor> = (0..capacity).map(|_| seed_state.clone())
            .collect();
        SamplePool {
            states: Tensor::stack(&parts).unwrap(),
            ages: vec![0; capacity],
            writes: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.ages.len()
    }

    /// Shape of one pool entry.
    pub fn entry_shape(&self) -> &[usize] {
        &self.states.shape()[1..]
    }

    /// Sample `batch` distinct indices and the stacked batch tensor
    /// [batch, ...state shape].
    pub fn sample(&self, batch: usize, rng: &mut Rng) -> (Vec<usize>, Tensor) {
        assert!(batch <= self.capacity(),
                "batch {batch} > pool capacity {}", self.capacity());
        let idx = rng.sample_indices(self.capacity(), batch);
        let parts: Vec<Tensor> =
            idx.iter().map(|&i| self.states.index_axis0(i)).collect();
        (idx.clone(), Tensor::stack(&parts).unwrap())
    }

    /// Write a batch back to the slots it was sampled from.
    pub fn write_back(&mut self, indices: &[usize], batch: &Tensor) {
        assert_eq!(batch.shape()[0], indices.len(),
                   "write_back: batch size mismatch");
        assert_eq!(&batch.shape()[1..], self.entry_shape(),
                   "write_back: entry shape mismatch");
        for age in &mut self.ages {
            *age += 1;
        }
        for (k, &i) in indices.iter().enumerate() {
            assert!(i < self.capacity(), "write_back: index {i} out of range");
            let sub = batch.index_axis0(k);
            self.states.set_axis0(i, &sub);
            self.ages[i] = 0;
        }
        self.writes += 1;
    }

    /// Damage injection (the regeneration-training half of the App. B
    /// recipe): zero a square patch — all channels — in each listed
    /// slot. The patch edge is `frac` of the shorter grid side (at
    /// least 1 cell) and its position is drawn from `rng`, so a seeded
    /// caller gets identical masks on every run. Entries must be at
    /// least rank 2 (`[H, W, ...]`). Returns the `(y0, x0, edge)` mask
    /// applied per slot.
    pub fn inject_damage(&mut self, indices: &[usize], frac: f32,
                         rng: &mut Rng) -> Vec<(usize, usize, usize)> {
        let shape = self.entry_shape().to_vec();
        assert!(shape.len() >= 2,
                "inject_damage wants [H, W, ...] entries, got {shape:?}");
        let (h, w) = (shape[0], shape[1]);
        let rest: usize = shape[2..].iter().product();
        let entry = h * w * rest;
        let edge = ((h.min(w) as f32 * frac).round() as usize)
            .clamp(1, h.min(w));
        let mut masks = Vec::with_capacity(indices.len());
        for &i in indices {
            assert!(i < self.capacity(),
                    "inject_damage: index {i} out of range");
            let y0 = rng.range(0, h - edge + 1);
            let x0 = rng.range(0, w - edge + 1);
            let data = self.states.data_mut();
            for y in y0..y0 + edge {
                let row = i * entry + (y * w + x0) * rest;
                data[row..row + edge * rest].fill(0.0);
            }
            masks.push((y0, x0, edge));
        }
        masks
    }

    /// Overwrite one slot with a fresh state (explicit reseed).
    pub fn reseed(&mut self, index: usize, state: &Tensor) {
        assert_eq!(state.shape(), self.entry_shape());
        self.states.set_axis0(index, state);
        self.ages[index] = 0;
    }

    /// The slot that has gone longest without a write-back.
    pub fn stalest(&self) -> usize {
        self.ages
            .iter()
            .enumerate()
            .max_by_key(|(_, &a)| a)
            .map(|(i, _)| i)
            .unwrap()
    }

    pub fn entry(&self, index: usize) -> Tensor {
        self.states.index_axis0(index)
    }

    pub fn writes(&self) -> u64 {
        self.writes
    }

    /// Mean age across slots (staleness metric).
    pub fn mean_age(&self) -> f64 {
        self.ages.iter().sum::<u64>() as f64 / self.capacity() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, Gen};
    use crate::prop_assert;

    fn seed_state() -> Tensor {
        let mut t = Tensor::zeros(&[4, 4, 2]);
        t.set(&[2, 2, 1], 1.0);
        t
    }

    #[test]
    fn initialized_with_seed_everywhere() {
        let pool = SamplePool::new(8, &seed_state());
        for i in 0..8 {
            assert!(pool.entry(i).bit_eq(&seed_state()));
        }
        assert_eq!(pool.capacity(), 8);
        assert_eq!(pool.entry_shape(), &[4, 4, 2]);
    }

    #[test]
    fn sample_indices_distinct_and_batch_matches() {
        let pool = SamplePool::new(16, &seed_state());
        let mut rng = Rng::new(1);
        let (idx, batch) = pool.sample(6, &mut rng);
        assert_eq!(idx.len(), 6);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 6);
        assert_eq!(batch.shape(), &[6, 4, 4, 2]);
        for (k, &i) in idx.iter().enumerate() {
            assert!(batch.index_axis0(k).bit_eq(&pool.entry(i)));
        }
    }

    #[test]
    fn write_back_updates_only_sampled_slots() {
        let mut pool = SamplePool::new(8, &seed_state());
        let mut rng = Rng::new(2);
        let (idx, mut batch) = pool.sample(3, &mut rng);
        batch.data_mut().iter_mut().for_each(|v| *v = 9.0);
        pool.write_back(&idx, &batch);
        for i in 0..8 {
            if idx.contains(&i) {
                assert_eq!(pool.entry(i).at(&[0, 0, 0]), 9.0);
            } else {
                assert!(pool.entry(i).bit_eq(&seed_state()));
            }
        }
    }

    #[test]
    fn ages_track_staleness() {
        let mut pool = SamplePool::new(4, &seed_state());
        let batch = Tensor::stack(&[seed_state()]).unwrap();
        pool.write_back(&[0], &batch);
        pool.write_back(&[1], &batch);
        pool.write_back(&[1], &batch);
        // Slot 2/3 never written: stalest. Slot 0 older than 1.
        let stalest = pool.stalest();
        assert!(stalest == 2 || stalest == 3);
        assert!(pool.mean_age() > 0.0);
        assert_eq!(pool.writes(), 3);
    }

    #[test]
    fn reseed_resets_slot() {
        let mut pool = SamplePool::new(4, &seed_state());
        let mut other = seed_state();
        other.set(&[0, 0, 0], 5.0);
        pool.reseed(2, &other);
        assert!(pool.entry(2).bit_eq(&other));
        assert!(pool.entry(1).bit_eq(&seed_state()));
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        // Same seed -> same batch indices AND same batch bits, across
        // fresh Rngs and fresh pools.
        let pool = SamplePool::new(16, &seed_state());
        let (idx_a, batch_a) = pool.sample(5, &mut Rng::new(0xBEEF));
        let (idx_b, batch_b) = pool.sample(5, &mut Rng::new(0xBEEF));
        assert_eq!(idx_a, idx_b);
        assert!(batch_a.bit_eq(&batch_b));
        let differs = (0..4u64)
            .any(|s| pool.sample(5, &mut Rng::new(0xBEE0 + s)).0 != idx_a);
        assert!(differs, "other seeds should eventually differ");
    }

    #[test]
    fn damage_masks_are_seed_deterministic() {
        let mut a = SamplePool::new(8, &seed_state());
        let mut b = SamplePool::new(8, &seed_state());
        let masks_a = a.inject_damage(&[1, 4, 6], 0.5, &mut Rng::new(31));
        let masks_b = b.inject_damage(&[1, 4, 6], 0.5, &mut Rng::new(31));
        assert_eq!(masks_a, masks_b);
        for i in 0..8 {
            assert!(a.entry(i).bit_eq(&b.entry(i)), "slot {i} diverged");
        }
    }

    #[test]
    fn damage_zeros_only_the_patch_in_listed_slots() {
        // A full-intensity pool makes the damaged region visible.
        let full = Tensor::full(&[4, 4, 2], 1.0);
        let mut pool = SamplePool::new(4, &full);
        let mut rng = Rng::new(7);
        let masks = pool.inject_damage(&[2], 0.5, &mut rng);
        assert_eq!(masks.len(), 1);
        let (y0, x0, edge) = masks[0];
        assert_eq!(edge, 2, "0.5 of a 4x4 grid");
        assert!(y0 + edge <= 4 && x0 + edge <= 4, "patch stays in bounds");
        // Untouched slots keep every value.
        for i in [0usize, 1, 3] {
            assert!(pool.entry(i).bit_eq(&full), "slot {i} touched");
        }
        // Damaged slot: zeros exactly inside the patch (all channels).
        let hit = pool.entry(2);
        for y in 0..4 {
            for x in 0..4 {
                for ch in 0..2 {
                    let inside = (y0..y0 + edge).contains(&y)
                        && (x0..x0 + edge).contains(&x);
                    let want = if inside { 0.0 } else { 1.0 };
                    assert_eq!(hit.at(&[y, x, ch]), want,
                               "({y},{x},{ch}) inside={inside}");
                }
            }
        }
    }

    #[test]
    fn damage_keeps_pool_invariants_under_reuse() {
        let mut pool = SamplePool::new(6, &seed_state());
        let mut rng = Rng::new(17);
        for round in 0..10u64 {
            let (idx, batch) = pool.sample(3, &mut rng);
            pool.write_back(&idx, &batch);
            pool.inject_damage(&idx[..1], 0.4, &mut rng);
            assert_eq!(pool.capacity(), 6, "round {round}");
            assert_eq!(pool.entry_shape(), &[4, 4, 2]);
            assert_eq!(pool.writes(), round + 1);
        }
    }

    #[test]
    #[should_panic]
    fn oversized_batch_panics() {
        let pool = SamplePool::new(4, &seed_state());
        let mut rng = Rng::new(3);
        pool.sample(5, &mut rng);
    }

    #[test]
    fn pool_invariants_property() {
        // Property: after arbitrary sample/write-back sequences the pool
        // capacity never changes, all entries keep the entry shape, and a
        // write-back is faithfully readable.
        check(0xC0FFEE, 100, |g: &mut Gen| {
            let cap = g.usize_in(2, 12);
            let mut pool = SamplePool::new(cap, &seed_state());
            for round in 0..g.usize_in(1, 8) {
                let b = g.usize_in(1, cap + 1).min(cap);
                let (idx, mut batch) = pool.sample(b, &mut g.rng);
                let stamp = round as f32 + 1.0;
                batch.data_mut().iter_mut().for_each(|v| *v = stamp);
                pool.write_back(&idx, &batch);
                prop_assert!(pool.capacity() == cap, "capacity changed");
                for &i in &idx {
                    prop_assert!(
                        pool.entry(i).at(&[0, 0, 0]) == stamp,
                        "write-back not visible at slot {i}"
                    );
                }
            }
            Ok(())
        })
        .unwrap();
    }
}
