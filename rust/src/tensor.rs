//! Dense f32 tensor — the host-side data currency of the whole coordinator.
//!
//! Deliberately minimal: shape + contiguous row-major `Vec<f32>`. Everything
//! crossing the PJRT boundary (states, parameters, batches, trajectories) is
//! a `Tensor`; integer/seed scalars cross as dedicated literal types in
//! `runtime::engine`.

use anyhow::{bail, Result};

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from shape + data (length must match).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            bail!(
                "Tensor::new: shape {:?} wants {} elements, got {}",
                shape, numel, data.len()
            );
        }
        Ok(Tensor { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let numel = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; numel] }
    }

    /// Filled with a constant.
    pub fn full(shape: &[usize], value: f32) -> Tensor {
        let numel = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![value; numel] }
    }

    /// A scalar (rank-0) tensor.
    pub fn scalar(value: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![value] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != self.data.len() {
            bail!("reshape: {:?} incompatible with {} elements", shape,
                  self.data.len());
        }
        self.shape = shape;
        Ok(self)
    }

    /// Flat offset of a multi-index (length must equal rank).
    pub fn offset(&self, index: &[usize]) -> usize {
        debug_assert_eq!(index.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in index.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < dim, "index {ix} out of bounds at axis {i}");
            off = off * dim + ix;
        }
        off
    }

    pub fn at(&self, index: &[usize]) -> f32 {
        self.data[self.offset(index)]
    }

    pub fn set(&mut self, index: &[usize], value: f32) {
        let off = self.offset(index);
        self.data[off] = value;
    }

    /// Slice out sub-tensor `i` along axis 0 (shares nothing; copies).
    pub fn index_axis0(&self, i: usize) -> Tensor {
        assert!(!self.shape.is_empty() && i < self.shape[0]);
        let sub: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * sub..(i + 1) * sub].to_vec(),
        }
    }

    /// Overwrite sub-tensor `i` along axis 0.
    pub fn set_axis0(&mut self, i: usize, sub: &Tensor) {
        assert!(!self.shape.is_empty() && i < self.shape[0]);
        let n: usize = self.shape[1..].iter().product();
        assert_eq!(sub.numel(), n, "set_axis0: size mismatch");
        self.data[i * n..(i + 1) * n].copy_from_slice(sub.data());
    }

    /// Stack equal-shaped tensors along a new axis 0.
    pub fn stack(parts: &[Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("stack: empty input");
        }
        let inner = parts[0].shape.clone();
        let mut data = Vec::with_capacity(parts.len() * parts[0].numel());
        for p in parts {
            if p.shape != inner {
                bail!("stack: shape mismatch {:?} vs {:?}", p.shape, inner);
            }
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(&inner);
        Ok(Tensor { shape, data })
    }

    /// Mean of all elements.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Mean squared difference against another tensor of identical shape.
    pub fn mse(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            bail!("mse: shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        let sum: f32 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        Ok(sum / self.data.len().max(1) as f32)
    }

    /// Largest absolute element difference (for equivalence tests).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            bail!("max_abs_diff: shape mismatch {:?} vs {:?}", self.shape,
                  other.shape);
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }

    /// True iff every element is bit-identical.
    pub fn bit_eq(&self, other: &Tensor) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_length() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn indexing_row_major() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect())
            .unwrap();
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn set_and_get() {
        let mut t = Tensor::zeros(&[3, 4]);
        t.set(&[2, 1], 7.5);
        assert_eq!(t.at(&[2, 1]), 7.5);
        assert_eq!(t.data().iter().filter(|&&x| x != 0.0).count(), 1);
    }

    #[test]
    fn axis0_roundtrip() {
        let t = Tensor::new(vec![2, 2, 2], (0..8).map(|i| i as f32).collect())
            .unwrap();
        let sub = t.index_axis0(1);
        assert_eq!(sub.shape(), &[2, 2]);
        assert_eq!(sub.data(), &[4.0, 5.0, 6.0, 7.0]);
        let mut t2 = t.clone();
        t2.set_axis0(0, &sub);
        assert_eq!(t2.index_axis0(0), sub);
    }

    #[test]
    fn stack_and_reshape() {
        let a = Tensor::full(&[2], 1.0);
        let b = Tensor::full(&[2], 2.0);
        let s = Tensor::stack(&[a, b]).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        let r = s.reshape(vec![4]).unwrap();
        assert_eq!(r.data(), &[1.0, 1.0, 2.0, 2.0]);
        assert!(r.clone().reshape(vec![3]).is_err());
        assert!(Tensor::stack(&[]).is_err());
        let c = Tensor::full(&[3], 0.0);
        assert!(Tensor::stack(&[Tensor::full(&[2], 0.0), c]).is_err());
    }

    #[test]
    fn metrics() {
        let a = Tensor::new(vec![4], vec![0.0, 1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![4], vec![1.0, 1.0, 2.0, 5.0]).unwrap();
        assert_eq!(a.mean(), 1.5);
        assert!((a.mse(&b).unwrap() - (1.0 + 4.0) / 4.0).abs() < 1e-6);
        assert_eq!(a.max_abs_diff(&b).unwrap(), 2.0);
        assert!(!a.bit_eq(&b));
        assert!(a.bit_eq(&a.clone()));
        assert!(a.mse(&Tensor::zeros(&[2])).is_err());
    }

    #[test]
    fn scalar_tensor() {
        let s = Tensor::scalar(2.5);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.numel(), 1);
    }
}
