//! E1/E2 — Figure 3 (left): classic-CA simulation speed, CAX-fused vs
//! per-step dispatch vs the naive per-cell baseline (the CellPyLib role).
//!
//! The paper reports 1,400x (ECA) and 2,000x (Life) over CellPyLib on an
//! A6000. Here all paths share one CPU, so the measured ratio isolates the
//! paper's mechanism (vectorization + one fused scan program); see
//! DESIGN.md §3 and EXPERIMENTS.md for the interpretation.

use cax::automata::WolframRule;
use cax::coordinator::{Path, Simulator};
use cax::runtime::Engine;
use cax::util::rng::Rng;

mod bench_util;
use bench_util::{bench, engine, header, quick, row};

/// Prefer the bench-scale artifact when the manifest carries it.
fn pick<'a>(engine: &Engine, bench_name: &'a str, fallback: &'a str)
            -> &'a str {
    if engine.manifest().artifacts.contains_key(bench_name) {
        bench_name
    } else {
        fallback
    }
}

fn main() {
    let engine = engine();
    let sim = Simulator::new(&engine);
    let mut rng = Rng::new(42);
    let (warm, iters) = if quick() { (1, 3) } else { (2, 10) };

    let eca_roll = pick(&engine, "eca_rollout_bench", "eca_rollout");
    let eca_step = pick(&engine, "eca_step_bench", "eca_step");
    let life_roll = pick(&engine, "life_rollout_bench", "life_rollout");
    let life_step = pick(&engine, "life_step_bench", "life_step");

    {
        let info = engine.manifest().artifact(eca_roll).unwrap();
        let steps = info.meta_usize("steps").unwrap();
        let (b, w) = (info.meta_usize("batch").unwrap(),
                      info.meta_usize("width").unwrap());
        header(&format!("Fig. 3 left — ECA rule 30 ({b}x{w}, {steps} steps)"));
        let state = sim.random_state(eca_roll, &mut rng).unwrap();
        let updates = sim.cell_updates(eca_roll, steps).unwrap();
        let rule = WolframRule::new(30);

        let fused = bench(warm, iters, || {
            sim.run_eca_named(eca_step, eca_roll, Path::Fused, &state, rule,
                              steps)
                .unwrap();
        });
        let stepwise = bench(warm.min(1), iters.min(5), || {
            sim.run_eca_named(eca_step, eca_roll, Path::Stepwise, &state,
                              rule, steps)
                .unwrap();
        });
        let naive = bench(warm, iters, || {
            sim.run_eca_named(eca_step, eca_roll, Path::Naive, &state, rule,
                              steps)
                .unwrap();
        });
        let native = bench(warm, iters, || {
            sim.run_eca_named(eca_step, eca_roll, Path::Native, &state,
                              rule, steps)
                .unwrap();
        });
        row("eca/cax-fused", &fused, updates);
        row("eca/xla-stepwise", &stepwise, updates);
        row("eca/naive-baseline", &naive, updates);
        row("eca/native-bitpacked", &native, updates);
        println!(
            "  speedup: fused is {:.1}x vs naive, {:.1}x vs stepwise; \
             native-bitpacked is {:.1}x vs naive \
             (paper: 1400x vs CellPyLib on GPU)",
            naive.median / fused.median,
            stepwise.median / fused.median,
            naive.median / native.median
        );
        if let Some(py) =
            cax::metrics::read_py_baseline(&bench_util::artifacts_dir())
        {
            println!(
                "  vs pure-Python per-cell baseline ({:.2e} upd/s): {:.0}x",
                py.eca_updates_per_s,
                (updates / fused.median) / py.eca_updates_per_s
            );
        }
    }

    {
        let info = engine.manifest().artifact(life_roll).unwrap();
        let steps = info.meta_usize("steps").unwrap();
        let (h, w) = (info.meta_usize("height").unwrap(),
                      info.meta_usize("width").unwrap());
        header(&format!("Fig. 3 left — Game of Life ({h}x{w}, {steps} \
                         steps)"));
        let state = sim.random_state(life_roll, &mut rng).unwrap();
        let updates = sim.cell_updates(life_roll, steps).unwrap();

        let fused = bench(warm, iters, || {
            sim.run_life_named(life_step, life_roll, Path::Fused, &state,
                               steps)
                .unwrap();
        });
        let stepwise = bench(warm.min(1), iters.min(5), || {
            sim.run_life_named(life_step, life_roll, Path::Stepwise, &state,
                               steps)
                .unwrap();
        });
        let naive = bench(warm.min(1), iters.min(4), || {
            sim.run_life_named(life_step, life_roll, Path::Naive, &state,
                               steps)
                .unwrap();
        });
        let native = bench(warm, iters, || {
            sim.run_life_named(life_step, life_roll, Path::Native, &state,
                               steps)
                .unwrap();
        });
        row("life/cax-fused", &fused, updates);
        row("life/xla-stepwise", &stepwise, updates);
        row("life/naive-baseline", &naive, updates);
        row("life/native-bitpacked", &native, updates);
        println!(
            "  speedup: fused is {:.1}x vs naive, {:.1}x vs stepwise; \
             native-bitpacked is {:.1}x vs naive \
             (paper: 2000x vs CellPyLib on GPU)",
            naive.median / fused.median,
            stepwise.median / fused.median,
            naive.median / native.median
        );
        if let Some(py) =
            cax::metrics::read_py_baseline(&bench_util::artifacts_dir())
        {
            println!(
                "  vs pure-Python per-cell baseline ({:.2e} upd/s): {:.0}x",
                py.life_updates_per_s,
                (updates / fused.median) / py.life_updates_per_s
            );
        }
    }

    header("Fig. 3 left — Lenia (continuous, FFT vs direct conv)");
    {
        let steps = engine
            .manifest()
            .artifact("lenia_rollout")
            .unwrap()
            .meta_usize("steps")
            .unwrap();
        let state = sim.random_state("lenia_rollout", &mut rng).unwrap();
        let updates = sim.cell_updates("lenia_rollout", steps).unwrap();

        let fused = bench(warm, iters, || {
            sim.run_lenia(Path::Fused, &state, steps).unwrap();
        });
        let stepwise = bench(warm, iters.min(5), || {
            sim.run_lenia(Path::Stepwise, &state, steps).unwrap();
        });
        let naive = bench(0, 2.min(iters), || {
            sim.run_lenia(Path::Naive, &state, steps).unwrap();
        });
        let native = bench(warm, iters.min(4), || {
            sim.run_lenia(Path::Native, &state, steps).unwrap();
        });
        row("lenia/cax-fused", &fused, updates);
        row("lenia/xla-stepwise", &stepwise, updates);
        row("lenia/naive-baseline", &naive, updates);
        row("lenia/native-tiled", &native, updates);
        println!(
            "  speedup: fused is {:.1}x vs naive (direct O(R^2) conv), \
             {:.1}x vs stepwise; native-tiled is {:.1}x vs naive",
            naive.median / fused.median,
            stepwise.median / fused.median,
            naive.median / native.median
        );
    }
}
