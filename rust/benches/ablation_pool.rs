//! Ablation — the sample pool (paper §3.2.2 / App. B).
//!
//! The paper credits the pool with stabilizing growing-NCA training: the
//! NCA keeps seeing its own developed states, making the target an
//! attractor rather than a waypoint. Ablation: train the same artifact
//! (a) with the Layer-3 pool (sample + write-back) and (b) with a fresh
//! seed batch every step, then compare the training losses AND the
//! stability metric that actually matters — MSE after rolling out PAST the
//! trained horizon (2x chained rollouts).
//!
//! Run: cargo bench --bench ablation_pool [-- --quick]

use cax::coordinator::experiments;
use cax::coordinator::trainer::{train_loop, TrainCfg, TrainState};
use cax::runtime::Value;
use cax::tensor::Tensor;

mod bench_util;
use bench_util::{engine, header, quick};

fn rgba_mse(state: &Tensor, target: &Tensor) -> f64 {
    let (h, w) = (target.shape()[0], target.shape()[1]);
    let mut sum = 0.0;
    for y in 0..h {
        for x in 0..w {
            for c in 0..4 {
                let d = (state.at(&[y, x, c]) - target.at(&[y, x, c])) as f64;
                sum += d * d;
            }
        }
    }
    sum / (h * w * 4) as f64
}

fn main() -> () {
    let engine = engine();
    let steps = if quick() { 120 } else { 400 };
    let seed = 7u32;
    let cfg = TrainCfg { steps, seed, log_every: 0, out_dir: None };
    let target = experiments::growing_target(&engine).unwrap();
    let seed_state = experiments::growing_seed(&engine).unwrap();

    header(&format!("ablation: sample pool vs fresh seeds ({steps} steps)"));

    // (a) With the pool.
    let (pool_run, _pool) =
        experiments::train_growing(&engine, &cfg, 64).unwrap();
    let (pf, pl_) = pool_run.history.window_means(20);

    // (b) Without the pool: fresh single-seed batch every step.
    let info = engine.manifest().artifact("growing_train_step").unwrap();
    let batch = info.inputs[4].shape[0];
    let fresh_batch = Tensor::stack(
        &(0..batch).map(|_| seed_state.clone()).collect::<Vec<_>>(),
    )
    .unwrap();
    let mut st = TrainState::from_blob(&engine, "growing_params").unwrap();
    let history = train_loop(
        &engine,
        "growing_train_step",
        &mut st,
        &cfg,
        |_| Ok(vec![Value::F32(fresh_batch.clone()),
                    Value::F32(target.clone())]),
        |_| Ok(()),
    )
    .unwrap();
    let (ff, fl) = history.window_means(20);

    println!("{:<22} {:>12} {:>12}", "variant", "loss first", "loss last");
    println!("{:<22} {:>12.5} {:>12.5}", "with-pool", pf, pl_);
    println!("{:<22} {:>12.5} {:>12.5}", "fresh-seeds", ff, fl);

    // Stability probe: chain 2 rollouts (2x the trained horizon) from the
    // seed and measure final MSE — the pool-trained NCA should hold the
    // pattern better (attractor), the no-pool one typically overshoots.
    let probe = |params: &Tensor, tag: &str| {
        let mut state = seed_state.clone();
        for r in 0..2 {
            let mut out = engine
                .execute(
                    "growing_rollout",
                    &[Value::F32(params.clone()), Value::F32(state),
                      Value::U32(100 + r)],
                )
                .unwrap();
            out.truncate(1);
            state = out.pop().unwrap();
        }
        let mse = rgba_mse(&state, &target);
        println!("{:<22} 2x-horizon rollout MSE {:.5}", tag, mse);
        mse
    };
    header("stability past the trained horizon (lower = stabler)");
    let with_pool = probe(&pool_run.state.params, "with-pool");
    let without = probe(&st.params, "fresh-seeds");
    println!(
        "\npool stability advantage: {:.2}x lower MSE at 2x horizon",
        without / with_pool.max(1e-12)
    );
}
