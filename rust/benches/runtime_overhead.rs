//! Runtime micro-benchmarks — the L3 hot path itself (DESIGN.md §7).
//!
//! Separates where each microsecond of an artifact call goes: compile
//! (once), literal marshalling in, execution, literal marshalling out.
//! The per-dispatch overhead measured here is exactly what the fused path
//! of Fig. 3 amortizes away; it also bounds how much the L3 coordinator
//! can matter relative to XLA compute.

use cax::runtime::{Engine, Value};
use cax::tensor::Tensor;
use cax::util::timer::Timer;

mod bench_util;
use bench_util::{bench, engine, header, quick, row};

fn main() {
    let engine = engine();
    let iters = if quick() { 20 } else { 200 };

    header("artifact compile cost (cold, one-time)");
    {
        // A fresh engine per artifact so every compile is cold.
        for name in ["eca_step", "life_step", "lenia_step",
                     "mnist_train_step"] {
            let cold = bench_util::engine();
            let t = Timer::start();
            cold.ensure_compiled(name).unwrap();
            println!("{:<40} {:>10.1} ms", name, t.elapsed_ms());
        }
    }

    header("per-dispatch overhead (tiny artifact, state reused)");
    {
        let info = engine.manifest().artifact("eca_step").unwrap();
        let state = Tensor::zeros(&info.inputs[0].shape.clone());
        let rule = Tensor::zeros(&[8]);
        let stats = bench(20, iters, || {
            engine
                .execute("eca_step",
                         &[Value::F32(state.clone()), Value::F32(rule.clone())])
                .unwrap();
        });
        row("eca_step single dispatch", &stats, state.numel() as f64);
        println!(
            "  -> per-dispatch floor ~{:.0} us; a T-step stepwise rollout \
             pays it T times, the fused path once",
            stats.median * 1e6
        );
    }

    header("marshalling cost vs payload size (life_step)");
    {
        let info = engine.manifest().artifact("life_step").unwrap();
        let shape = info.inputs[0].shape.clone();
        let numel: usize = shape.iter().product();
        let state = Tensor::zeros(&shape);
        let stats = bench(10, iters, || {
            engine.execute("life_step", &[Value::F32(state.clone())]).unwrap();
        });
        row(&format!("life_step dispatch ({numel} f32 in/out)"), &stats,
            numel as f64);
    }

    header("train-step dispatch (params round-trip)");
    {
        let params = engine.load_params("mnist_params").unwrap();
        let n = params.numel();
        let info = engine.manifest().artifact("mnist_train_step").unwrap();
        let dspec = &info.inputs[4];
        let lspec = &info.inputs[5];
        let digits = Tensor::zeros(&dspec.shape.clone());
        let labels = Tensor::zeros(&lspec.shape.clone());
        let m = Tensor::zeros(&[n]);
        let v = Tensor::zeros(&[n]);
        let stats = bench(2, (iters / 10).max(3), || {
            engine
                .execute(
                    "mnist_train_step",
                    &[
                        Value::F32(params.clone()),
                        Value::F32(m.clone()),
                        Value::F32(v.clone()),
                        Value::I32(0),
                        Value::F32(digits.clone()),
                        Value::F32(labels.clone()),
                        Value::U32(1),
                    ],
                )
                .unwrap();
        });
        row(&format!("mnist_train_step ({n} params x3 buffers)"), &stats, 1.0);
    }

    let s: cax::runtime::EngineStats = engine.stats();
    header("engine cumulative stats");
    println!(
        "compiles {}  executions {}  compile {:.2}s  execute {:.2}s  \
         in {:.1} MB  out {:.1} MB",
        s.compiles, s.executions, s.compile_secs, s.execute_secs,
        s.bytes_in as f64 / 1e6, s.bytes_out as f64 / 1e6
    );
    let _: &Engine = &engine;
}
