//! Fig. 3 (left) on the native backend — naive per-cell baselines vs the
//! bit-packed/tiled multi-threaded kernels, with no artifacts, XLA or
//! Python anywhere. When built with `--features pjrt` AND artifacts are
//! present, fused XLA rows are appended for the three-way comparison.
//!
//! Emits `BENCH_native.json` (cells/sec per row) so the performance
//! trajectory of the native path is tracked from this PR on.
//!
//! Run: cargo bench --bench fig3_native [-- --quick]
//! Acceptance anchor: bit-packed Life >= 20x LifeSim on 256x256 x batch 32.

use cax::automata::lenia::LeniaParams;
use cax::automata::{EcaSim, LeniaSim, LifeSim, WolframRule};
use cax::backend::native::nca::NcaModel;
use cax::backend::{Backend, CaProgram, NativeBackend};
use cax::metrics::BenchRow;
use cax::tensor::Tensor;
use cax::util::rng::Rng;

mod bench_util;
use bench_util::{bench, finish, header, push, quick, soft};

fn main() {
    let backend = NativeBackend::new();
    let mut rng = Rng::new(42);
    let mut rows: Vec<BenchRow> = vec![];
    let (warm, iters) = if quick() { (1, 3) } else { (2, 8) };
    println!("native backend: {} worker threads", backend.threads());

    // ----------------------------------------------------------- ECA
    {
        let (b, w, steps) = if quick() { (8, 512, 64) } else {
            (32, 1024, 256)
        };
        header(&format!("Fig. 3 left — ECA rule 30 ({b}x{w}, {steps} steps, \
                         native)"));
        let state =
            Tensor::new(vec![b, w], rng.binary_vec(b * w, 0.5)).unwrap();
        let updates = (b * w * steps) as f64;
        let rule = WolframRule::new(30);
        let prog = CaProgram::Eca { rule };

        let naive = bench(warm.min(1), iters.min(4), || {
            let mut sim = EcaSim::from_tensor(rule, &state);
            sim.run(steps);
        });
        let native = bench(warm, iters, || {
            backend.rollout(&prog, &state, steps).unwrap();
        });
        push(&mut rows, "eca/naive-baseline", &naive, updates);
        push(&mut rows, "eca/native-bitpacked", &native, updates);
        println!("  speedup: native-bitpacked is {:.1}x vs naive",
                 naive.median / native.median);
    }

    // ---------------------------------------------------------- Life
    {
        let (b, h, w) = (32, 256, 256);
        let steps = if quick() { 4 } else { 16 };
        header(&format!("Fig. 3 left — Game of Life ({b}x{h}x{w}, {steps} \
                         steps, native)"));
        let state = Tensor::new(vec![b, h, w],
                                rng.binary_vec(b * h * w, 0.4))
            .unwrap();
        let updates = (b * h * w * steps) as f64;

        let naive = bench(0, 2.min(iters), || {
            let mut sim = LifeSim::from_tensor(&state);
            sim.run(steps);
        });
        let native = bench(warm, iters, || {
            backend.rollout(&CaProgram::Life, &state, steps).unwrap();
        });
        push(&mut rows, "life/naive-baseline", &naive, updates);
        push(&mut rows, "life/native-bitpacked", &native, updates);
        let speedup = naive.median / native.median;
        println!(
            "  speedup: native-bitpacked is {speedup:.1}x vs naive \
             (acceptance target: >= 20x on this very grid)"
        );
    }

    // ------------------------------- sparse occupancy (activity map)
    // The anchor for the activity-tracked stepping: a lone Gosper
    // glider gun on a 4096^2 torus — ~10^-5 occupancy, the regime the
    // per-tile dirty maps exist for. Dense SWAR pays all 256Ki
    // word-tiles every step; the sparse path recomputes only the
    // gun/glider neighborhood after the first (dense, map-warming)
    // step. HashLife rides along as the memoizing extreme.
    {
        use cax::backend::native::activity::ActivityMap;
        use cax::backend::native::hashlife::LifeHash;
        use cax::backend::native::life::LifeKernel;
        use cax::backend::native::bits;

        let size = 4096usize;
        let steps = if quick() { 48 } else { 128 };
        header(&format!("Sparse occupancy — Gosper glider gun \
                         ({size}x{size}, {steps} steps, native)"));
        const GUN: [(usize, usize); 36] = [
            (0, 4), (0, 5), (1, 4), (1, 5), (10, 4), (10, 5), (10, 6),
            (11, 3), (11, 7), (12, 2), (12, 8), (13, 2), (13, 8),
            (14, 5), (15, 3), (15, 7), (16, 4), (16, 5), (16, 6),
            (17, 5), (20, 2), (20, 3), (20, 4), (21, 2), (21, 3),
            (21, 4), (22, 1), (22, 5), (24, 0), (24, 1), (24, 5),
            (24, 6), (34, 2), (34, 3), (35, 2), (35, 3),
        ];
        let wpr = bits::words_for(size);
        let mut gun = vec![0u64; size * wpr];
        for &(x, y) in &GUN {
            let (gx, gy) = (x + size / 2, y + size / 2);
            gun[gy * wpr + gx / 64] |= 1 << (gx % 64);
        }
        let updates = (size * size * steps) as f64;

        let dense = bench(warm.min(1), iters.min(4), || {
            let mut grid = gun.clone();
            let mut kern = LifeKernel::new(size, size);
            kern.rollout(&mut grid, steps);
        });
        let sparse = bench(warm.min(1), iters.min(4), || {
            let mut grid = gun.clone();
            let mut kern = LifeKernel::new(size, size);
            let mut map = ActivityMap::new(0, size, wpr);
            kern.rollout_sparse(&mut grid, steps, &mut map);
        });
        let quad = bench(warm.min(1), iters.min(4), || {
            let mut grid = gun.clone();
            LifeHash::default().advance(&mut grid, size, steps);
        });
        push(&mut rows, "life-sparse/dense-swar", &dense, updates);
        push(&mut rows, "life-sparse/activity-tracked", &sparse, updates);
        push(&mut rows, "life-sparse/hashlife", &quad, updates);
        let sparse_speedup = dense.median / sparse.median;
        println!("  speedup: activity-tracked is {sparse_speedup:.1}x vs \
                  dense SWAR (acceptance target: >= 10x), hashlife \
                  {:.1}x", dense.median / quad.median);
        if sparse_speedup < 10.0 {
            assert!(soft(),
                    "sparse acceptance: {sparse_speedup:.2}x < 10x on \
                     the gun sweep");
            println!("  (soft mode: not failing on the 10x target)");
        }
    }

    // --------------------------------------------------------- Lenia
    {
        let (b, size) = if quick() { (2, 64) } else { (4, 128) };
        let steps = if quick() { 4 } else { 16 };
        let params = LeniaParams::default();
        header(&format!("Fig. 3 left — Lenia ({b}x{size}x{size}, R={}, \
                         {steps} steps, native)", params.radius));
        let mut boards = Vec::new();
        for _ in 0..b {
            let sim = LeniaSim::random_patch(params, size, size / 2,
                                             &mut rng);
            boards.push(sim.state().clone());
        }
        let state = Tensor::stack(&boards).unwrap();
        let updates = (b * size * size * steps) as f64;

        let naive = bench(0, 2.min(iters), || {
            for i in 0..b {
                let mut sim = LeniaSim::new(params, state.index_axis0(i));
                sim.run(steps);
            }
        });
        let native = bench(warm.min(1), iters.min(4), || {
            backend
                .rollout(&CaProgram::Lenia { params }, &state, steps)
                .unwrap();
        });
        push(&mut rows, "lenia/naive-baseline", &naive, updates);
        push(&mut rows, "lenia/native-tiled", &native, updates);
        println!("  speedup: native-tiled is {:.1}x vs naive",
                 naive.median / native.median);
    }

    // ----------------------------------------------------------- NCA
    {
        let (b, size, c, hidden) = if quick() { (2, 32, 8, 32) } else {
            (4, 64, 16, 64)
        };
        let steps = if quick() { 2 } else { 8 };
        header(&format!("NCA forward cell ({b}x{size}x{size}x{c}, hidden \
                         {hidden}, {steps} steps, native)"));
        let model = NcaModel::random(c, hidden, &mut rng);
        let state = Tensor::new(vec![b, size, size, c],
                                rng.vec_f32(b * size * size * c))
            .unwrap();
        let updates = (b * size * size * steps) as f64;
        let prog = CaProgram::Nca(model);
        let native = bench(warm.min(1), iters.min(4), || {
            backend.rollout(&prog, &state, steps).unwrap();
        });
        push(&mut rows, "nca/native-depthwise", &native, updates);
    }

    // ------------------------------------- SIMD vs scalar dispatch
    // The three vectorized f32 hot loops against their always-compiled
    // scalar references (bit-identical output — `native_simd_props`
    // proves it; these rows measure what the identity costs/buys).
    {
        use cax::backend::native::lenia::{
            update_stage, update_stage_scalar, LeniaKernel,
        };
        use cax::backend::native::simd;

        header(&format!("SIMD vs scalar f32 kernels — dispatch: {}",
                        simd::status()));

        // Lenia growth/update stage (shared by the spectral path):
        // 3 kernels mixing into one channel.
        let hw = if quick() { 128 * 128 } else { 256 * 256 };
        let reps = if quick() { 10 } else { 40 };
        let wk = [0.5f32, 0.25, 0.25];
        let gs_state = rng.vec_f32(hw);
        let growths = rng.vec_f32(wk.len() * hw);
        let mut next = vec![0.0f32; hw];
        let dispatch = bench(warm, iters, || {
            for _ in 0..reps {
                update_stage(&gs_state, &growths, hw, &wk, 0.1, &mut next);
            }
        });
        let scalar = bench(warm, iters, || {
            for _ in 0..reps {
                update_stage_scalar(&gs_state, &growths, hw, &wk, 0.1,
                                    &mut next);
            }
        });
        let updates = (hw * reps) as f64;
        push(&mut rows, "lenia-growth/simd-dispatch", &dispatch, updates);
        push(&mut rows, "lenia-growth/scalar", &scalar, updates);
        let growth_speedup = scalar.median / dispatch.median;
        println!("  speedup: dispatching growth stage is \
                  {growth_speedup:.1}x vs scalar");

        // Lenia sparse-tap convolution.
        let (size, radius, steps) =
            if quick() { (96, 8, 2) } else { (192, 10, 4) };
        let kernel = LeniaKernel::new(LeniaParams {
            radius,
            ..Default::default()
        });
        let board0 = rng.vec_f32(size * size);
        let conv_dispatch = bench(warm, iters, || {
            let mut board = board0.clone();
            let mut scratch = vec![0.0f32; board.len()];
            kernel.rollout(&mut board, &mut scratch, size, size, steps);
        });
        let conv_scalar = bench(warm, iters, || {
            let mut board = board0.clone();
            let mut scratch = vec![0.0f32; board.len()];
            for _ in 0..steps {
                kernel.step_scalar(&board, &mut scratch, size, size);
                board.copy_from_slice(&scratch);
            }
        });
        let updates = (size * size * steps) as f64;
        push(&mut rows, "lenia-sparse/simd-dispatch", &conv_dispatch,
             updates);
        push(&mut rows, "lenia-sparse/scalar", &conv_scalar, updates);
        println!("  speedup: dispatching sparse-tap is {:.1}x vs scalar",
                 conv_scalar.median / conv_dispatch.median);

        // NCA perceive + MLP cell.
        let (nh, nw, c, hidden, nsteps) = if quick() {
            (32, 32, 8, 32, 2)
        } else {
            (64, 64, 16, 64, 4)
        };
        let model = NcaModel::random(c, hidden, &mut rng);
        let nca_board = rng.vec_f32(nh * nw * c);
        let nca_dispatch = bench(warm, iters, || {
            let mut board = nca_board.clone();
            let mut scratch = vec![0.0f32; board.len()];
            model.rollout(&mut board, &mut scratch, nh, nw, nsteps);
        });
        let nca_scalar = bench(warm, iters, || {
            let mut board = nca_board.clone();
            let mut scratch = vec![0.0f32; board.len()];
            for _ in 0..nsteps {
                model.step_frozen_scalar(&board, &mut scratch, nh, nw, 0);
                board.copy_from_slice(&scratch);
            }
        });
        let updates = (nh * nw * nsteps) as f64;
        push(&mut rows, "nca-cell/simd-dispatch", &nca_dispatch, updates);
        push(&mut rows, "nca-cell/scalar", &nca_scalar, updates);
        let nca_speedup = nca_scalar.median / nca_dispatch.median;
        println!("  speedup: dispatching NCA cell is {nca_speedup:.1}x \
                  vs scalar");

        // Acceptance: the AVX2 growth stage and NCA cell are >= 2x
        // their scalar forms (only meaningful when avx2 dispatched and
        // iteration counts are not trimmed).
        if simd::active() && !quick() {
            let msg = format!(
                "SIMD acceptance: growth {growth_speedup:.2}x, nca \
                 {nca_speedup:.2}x (target >= 2x each)"
            );
            println!("  {msg}");
            if growth_speedup < 2.0 || nca_speedup < 2.0 {
                assert!(soft(), "{msg}");
                println!("  (soft mode: not failing on the 2x target)");
            }
        }
    }

    // Fused XLA rows ride along when the build + artifacts allow it.
    #[cfg(feature = "pjrt")]
    {
        use cax::coordinator::{Path, Simulator};
        if let Ok(engine) =
            cax::runtime::Engine::load(&bench_util::artifacts_dir())
        {
            let sim = Simulator::new(&engine);
            header("Fig. 3 left — fused XLA rows (pjrt)");
            for (ca, artifact) in
                [("eca", "eca_rollout"), ("life", "life_rollout"),
                 ("lenia", "lenia_rollout")]
            {
                let Ok(info) = engine.manifest().artifact(artifact) else {
                    continue;
                };
                let steps = info.meta_usize("steps").unwrap_or(64);
                let state = sim.random_state(artifact, &mut rng).unwrap();
                let updates = sim.cell_updates(artifact, steps).unwrap();
                let rule = WolframRule::new(30);
                let stats = bench(warm.min(1), iters.min(4), || {
                    match ca {
                        "eca" => sim
                            .run_eca(Path::Fused, &state, rule, steps)
                            .unwrap(),
                        "life" => {
                            sim.run_life(Path::Fused, &state, steps).unwrap()
                        }
                        _ => {
                            sim.run_lenia(Path::Fused, &state, steps)
                                .unwrap()
                        }
                    };
                });
                push(&mut rows, &format!("{ca}/cax-fused"), &stats, updates);
            }
        } else {
            println!("\n(pjrt enabled but no artifacts found; skipping \
                      fused rows)");
        }
    }

    let out = std::path::Path::new("BENCH_native.json");
    finish("fig3_native", &rows, out);
}
