//! Minimal bench harness shared by the `cargo bench` targets (criterion is
//! not available offline; this prints comparable median/mean/p95 rows and
//! honors the same warmup/measure protocol everywhere).

use std::path::PathBuf;

use cax::util::timer::{Stats, Timer};

#[allow(dead_code)]
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CAX_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A fresh PJRT engine over the build's artifacts (pjrt-only benches).
#[cfg(feature = "pjrt")]
#[allow(dead_code)]
pub fn engine() -> cax::runtime::Engine {
    cax::runtime::Engine::load(&artifacts_dir())
        .expect("run `make artifacts` first")
}

/// Quick mode trims iteration counts (CAX_BENCH_QUICK=1 or `--quick`).
pub fn quick() -> bool {
    std::env::var("CAX_BENCH_QUICK").is_ok()
        || std::env::args().any(|a| a == "--quick")
}

/// Soft mode (CAX_BENCH_SOFT=1 or `--soft`) downgrades performance
/// acceptance asserts to warnings — for noisy shared CI runners where
/// the numbers are still worth recording but not worth failing on.
/// Correctness asserts (counters, histogram shapes) stay hard.
#[allow(dead_code)]
pub fn soft() -> bool {
    std::env::var("CAX_BENCH_SOFT").is_ok()
        || std::env::args().any(|a| a == "--soft")
}

/// Time `f` with warmup; returns wall-clock stats over `iters` runs.
#[allow(dead_code)]
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_secs());
    }
    Stats::from_samples(&samples)
}

/// Print one result row and collect it for the bench-report JSON.
#[allow(dead_code)]
pub fn push(rows: &mut Vec<cax::metrics::BenchRow>, label: &str,
            stats: &Stats, items_per_iter: f64) {
    row(label, stats, items_per_iter);
    rows.push(cax::metrics::BenchRow {
        label: label.to_string(),
        stats: stats.clone(),
        items_per_iter,
    });
}

/// Print one result row: name, median, mean, p95, p99, throughput (the
/// rate math lives in `cax::metrics::per_second`, shared with the sim
/// and serve surfaces).
#[allow(dead_code)]
pub fn row(name: &str, stats: &Stats, items: f64) {
    println!(
        "{:<40} median {:>10.4}s  mean {:>10.4}s  p95 {:>10.4}s  \
         p99 {:>10.4}s  {:>12.3e}/s",
        name, stats.median, stats.mean, stats.p95, stats.p99,
        cax::metrics::per_second(items, stats.median)
    );
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// The committed bench baselines (`BENCH_*.json` seeds the `cax bench
/// compare` gate diffs against).
#[allow(dead_code)]
pub fn baselines_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("benches/baselines")
}

/// `--update-baseline` (or CAX_BENCH_UPDATE_BASELINE=1): the ONLY way
/// a run may overwrite a committed baseline.
#[allow(dead_code)]
pub fn update_baseline() -> bool {
    std::env::var("CAX_BENCH_UPDATE_BASELINE").is_ok()
        || std::env::args().any(|a| a == "--update-baseline")
}

/// Write the bench report (which also appends the run to
/// `BENCH_history.jsonl` next to it), then reconcile with the
/// committed baseline at `benches/baselines/<file>`:
///
/// - under [`update_baseline`], the fresh report replaces the
///   baseline (explicitly, never silently);
/// - otherwise the baseline is left untouched and the run is diffed
///   against it, printing per-row drift — informational here; the
///   hard/soft gate is `cax bench compare` in CI.
#[allow(dead_code)]
pub fn finish(name: &str, rows: &[cax::metrics::BenchRow],
              out: &std::path::Path) {
    use cax::metrics::bench_history;
    cax::metrics::write_bench_report(name, rows, out)
        .expect("writing bench report");
    println!("\nwrote {}", out.display());
    let baseline =
        baselines_dir().join(out.file_name().expect("report filename"));
    if update_baseline() {
        std::fs::create_dir_all(baselines_dir())
            .expect("creating baselines dir");
        std::fs::copy(out, &baseline).expect("updating baseline");
        println!("updated baseline {}", baseline.display());
        return;
    }
    if !baseline.exists() {
        println!(
            "no committed baseline at {} (pass --update-baseline to \
             seed one)",
            baseline.display()
        );
        return;
    }
    match bench_history::compare_files(out, &baseline) {
        Ok(cmp) => {
            let t = bench_history::DEFAULT_THRESHOLD;
            for d in cmp.regressions(t) {
                println!(
                    "WARN: {} median {:.6}s vs baseline {:.6}s \
                     ({:+.1}%)",
                    d.label, d.current_s, d.baseline_s,
                    100.0 * d.slowdown()
                );
            }
            for label in &cmp.missing {
                println!(
                    "WARN: baseline row {label:?} missing from this run"
                );
            }
            if cmp.passed(t) {
                println!(
                    "baseline check: {} rows within +{:.0}% of {}",
                    cmp.deltas.len(),
                    100.0 * t,
                    baseline.display()
                );
            }
        }
        Err(e) => println!("WARN: baseline compare failed: {e:#}"),
    }
}
