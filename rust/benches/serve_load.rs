//! Serve-layer load generator: coalesced multi-session stepping vs the
//! same sessions stepped solo.
//!
//! The serving claim of `cax::serve` is that N sessions running the
//! same program should ride ONE batched backend launch per tick (kept
//! backend-resident between ticks), instead of N solo `rollout` calls
//! that each re-cross the f32 boundary and run single-board. This
//! bench drives the real [`Coalescer`] (queue, grouping, scatter — no
//! HTTP) against that solo baseline and emits `BENCH_serve.json`.
//!
//! Two non-throughput scenarios ride along:
//!
//! - **obs overhead**: the same Life rollout with `cax::obs` span
//!   recording off vs on — the observability contract says
//!   instrumentation costs < 2% (soft-able via `--soft`).
//! - **overload**: a tiny coalescer (max_pending 16) is driven past its
//!   queue bound; the 503 counter, queue-depth high-water mark and
//!   wait-latency histogram must all report the abuse exactly.
//!
//! Run: cargo bench --bench serve_load [-- --quick] [-- --soft]
//! Acceptance anchor: >= 5x aggregate session-steps/sec for 64
//! coalesced Life 256x256 sessions vs the same sessions stepped solo.

use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::time::Duration;

use cax::automata::lenia::LeniaParams;
use cax::automata::WolframRule;
use cax::backend::{Backend, CaProgram, NativeBackend};
use cax::metrics::BenchRow;
use cax::obs;
use cax::serve::{Coalescer, ProgramSpec, ServeConfig, StepRequest};
use cax::tensor::Tensor;
use cax::util::rng::Rng;

mod bench_util;
use bench_util::{bench, finish, header, push, quick, soft};

/// Submit one step request per session, tick until all are served, and
/// drain the replies — one coalesced "frame" of the service.
fn coalesced_round(c: &Coalescer, ids: &[u64], steps: usize) {
    let (tx, rx) = channel();
    for &id in ids {
        c.submit(StepRequest::new(id, steps, tx.clone()))
            .expect("submit");
    }
    drop(tx);
    let mut served = 0;
    while served < ids.len() {
        served += c.tick();
    }
    for _ in 0..ids.len() {
        rx.recv().expect("reply").expect("step ok");
    }
}

/// Step every board through its own single-board backend call — the
/// pre-serve cost structure (fresh f32 boundary + allocation per call,
/// no cross-session batching).
fn solo_round(backend: &NativeBackend, prog: &CaProgram,
              boards: &mut [Tensor], steps: usize) {
    for board in boards.iter_mut() {
        *board = backend.rollout(prog, board, steps).expect("solo rollout");
    }
}

fn sessions(c: &Coalescer, spec: &ProgramSpec, n: usize) -> Vec<u64> {
    let mut reg = c.registry().lock().unwrap();
    (0..n)
        .map(|_| reg.create(c.backend(), spec.clone(), None).unwrap())
        .collect()
}

fn main() {
    let cfg = ServeConfig {
        max_sessions: 256,
        max_batch: 64,
        max_pending: 4096,
        tick_window: Duration::ZERO,
        seed: 42,
        ..ServeConfig::default()
    };
    let coalescer = Coalescer::new(&cfg);
    let backend = NativeBackend::new();
    let mut rng = Rng::new(42);
    let mut rows: Vec<BenchRow> = vec![];
    let (warm, iters, rounds) = if quick() { (1, 3, 2) } else { (1, 5, 8) };
    println!(
        "serve load generator: {} worker threads, max batch {}",
        coalescer.backend().threads(),
        cfg.max_batch
    );

    // ---------------------------------------- obs span overhead row
    // The observability contract (rust/src/obs) promises that span
    // recording perturbs kernel timing by < 2%. Measure the same Life
    // rollout with recording globally off, then on (the default).
    {
        let (h, w, calls) = (256, 256, 32);
        header(&format!(
            "obs — span overhead on Life {h}x{w}, {calls} rollouts/iter \
             (recording off vs on)"
        ));
        let prog = CaProgram::Life;
        let mut board =
            Tensor::new(vec![1, h, w], rng.binary_vec(h * w, 0.5)).unwrap();

        obs::set_recording(false);
        let off = bench(warm, iters, || {
            for _ in 0..calls {
                board = backend.rollout(&prog, &board, 1).unwrap();
            }
        });
        obs::set_recording(true);
        let on = bench(warm, iters, || {
            for _ in 0..calls {
                board = backend.rollout(&prog, &board, 1).unwrap();
            }
        });

        push(&mut rows, "obs/life-256x256/recording-off", &off,
             calls as f64);
        push(&mut rows, "obs/life-256x256/recording-on", &on,
             calls as f64);
        let overhead = on.median / off.median - 1.0;
        println!(
            "  span overhead: {:.3}% of kernel time (target: < 2%)",
            overhead * 100.0
        );
        if soft() {
            if overhead >= 0.02 {
                println!(
                    "  WARN (soft mode): overhead {:.3}% >= 2%",
                    overhead * 100.0
                );
            }
        } else {
            assert!(
                overhead < 0.02,
                "obs span overhead must stay < 2% (got {:.3}%)",
                overhead * 100.0
            );
        }
    }

    // ------------------------------------------------- Life (anchor)
    let speedup = {
        let (n, h, w) = (64, 256, 256);
        header(&format!(
            "serve — {n} Life {h}x{w} sessions, 1 step/request \
             (coalesced vs solo)"
        ));
        let spec = ProgramSpec::Life { height: h, width: w };
        let ids = sessions(&coalescer, &spec, n);
        let steps_per_iter = (n * rounds) as f64;

        let coalesced = bench(warm, iters, || {
            for _ in 0..rounds {
                coalesced_round(&coalescer, &ids, 1);
            }
        });

        let prog = CaProgram::Life;
        let mut boards: Vec<Tensor> = (0..n)
            .map(|_| {
                Tensor::new(vec![1, h, w], rng.binary_vec(h * w, 0.5))
                    .unwrap()
            })
            .collect();
        let solo = bench(warm, iters.min(3), || {
            for _ in 0..rounds {
                solo_round(&backend, &prog, &mut boards, 1);
            }
        });

        // A third arm for context: one batched rollout call over a
        // [64, H, W] tensor — batching without residency (pays the
        // boundary once per call, but for all boards).
        let mut big = Tensor::new(
            vec![n, h, w],
            rng.binary_vec(n * h * w, 0.5),
        )
        .unwrap();
        let batched = bench(warm, iters.min(3), || {
            for _ in 0..rounds {
                big = backend.rollout(&prog, &big, 1).unwrap();
            }
        });

        push(&mut rows, "serve/life-64x256x256/coalesced", &coalesced,
             steps_per_iter);
        push(&mut rows, "serve/life-64x256x256/solo", &solo,
             steps_per_iter);
        push(&mut rows, "serve/life-64x256x256/batched-rollout", &batched,
             steps_per_iter);
        let speedup = solo.median / coalesced.median;
        println!(
            "  speedup: coalesced resident stepping is {speedup:.1}x vs \
             solo (acceptance target: >= 5x)"
        );
        speedup
    };

    // ------------------------------------------------------ ECA rows
    {
        let (n, w) = (64, 1024);
        header(&format!(
            "serve — {n} ECA rule-30 width-{w} sessions, 4 steps/request"
        ));
        let spec = ProgramSpec::Eca { rule: 30, width: w };
        let ids = sessions(&coalescer, &spec, n);
        let steps_per_iter = (n * rounds * 4) as f64;
        let coalesced = bench(warm, iters, || {
            for _ in 0..rounds {
                coalesced_round(&coalescer, &ids, 4);
            }
        });
        let prog = CaProgram::Eca { rule: WolframRule::new(30) };
        let mut boards: Vec<Tensor> = (0..n)
            .map(|_| {
                Tensor::new(vec![1, w], rng.binary_vec(w, 0.5)).unwrap()
            })
            .collect();
        let solo = bench(warm, iters.min(3), || {
            for _ in 0..rounds {
                solo_round(&backend, &prog, &mut boards, 4);
            }
        });
        push(&mut rows, "serve/eca-64x1024/coalesced", &coalesced,
             steps_per_iter);
        push(&mut rows, "serve/eca-64x1024/solo", &solo, steps_per_iter);
        println!("  speedup: {:.1}x", solo.median / coalesced.median);
    }

    // -------------------------------------- spectral Lenia plan reuse
    {
        // Radius 32 at 128x128 runs the FFT kernel: a solo call builds
        // the spectral plan per session per call; the coalesced tick
        // builds it once per batch.
        let (n, size, radius) = (16, 128, 32);
        header(&format!(
            "serve — {n} Lenia r{radius} {size}x{size} sessions (fft \
             path), 1 step/request"
        ));
        let spec = ProgramSpec::Lenia {
            radius,
            height: size,
            width: size,
        };
        let ids = sessions(&coalescer, &spec, n);
        let steps_per_iter = (n * rounds) as f64;
        let coalesced = bench(warm, iters.min(3), || {
            for _ in 0..rounds {
                coalesced_round(&coalescer, &ids, 1);
            }
        });
        let prog = CaProgram::Lenia {
            params: LeniaParams { radius, ..Default::default() },
        };
        let mut boards: Vec<Tensor> = (0..n)
            .map(|_| {
                Tensor::new(vec![1, size, size],
                            rng.binary_vec(size * size, 0.5))
                .unwrap()
            })
            .collect();
        let solo = bench(warm, iters.min(2), || {
            for _ in 0..rounds {
                solo_round(&backend, &prog, &mut boards, 1);
            }
        });
        push(&mut rows, "serve/lenia-16xr32x128/coalesced", &coalesced,
             steps_per_iter);
        push(&mut rows, "serve/lenia-16xr32x128/solo", &solo,
             steps_per_iter);
        println!("  speedup: {:.1}x", solo.median / coalesced.median);
    }

    // ------------------------------------------------ idle-fleet rows
    // The serving regime activity tracking exists for: a fleet of
    // parked sessions whose soups have burned down to still lifes and
    // oscillators, re-stepped every tick. Dense stepping pays the full
    // board per tick; the sparse path recomputes only the tiles around
    // the surviving oscillators. The skipped-tile counter moving is a
    // hard correctness assert; the CPU drop is the performance row.
    {
        use cax::backend::native::activity;

        let (n, size) = (32, 256);
        header(&format!(
            "serve — idle fleet: {n} settled Life {size}x{size} sessions, \
             1 step/request (dense vs activity-tracked)"
        ));
        let spec = ProgramSpec::Life { height: size, width: size };
        let ids = sessions(&coalescer, &spec, n);
        // Burn the soups down to their ash (still lifes + blinkers).
        activity::set_override(Some(false));
        for _ in 0..8 {
            coalesced_round(&coalescer, &ids, 40);
        }
        let steps_per_iter = (n * rounds) as f64;
        let dense = bench(warm, iters, || {
            for _ in 0..rounds {
                coalesced_round(&coalescer, &ids, 1);
            }
        });
        activity::set_override(Some(true));
        let skipped_before = activity::tiles_skipped_total();
        let sparse = bench(warm, iters, || {
            for _ in 0..rounds {
                coalesced_round(&coalescer, &ids, 1);
            }
        });
        let skipped_after = activity::tiles_skipped_total();
        activity::set_override(None);
        push(&mut rows, "serve/idle-32x256x256/dense", &dense,
             steps_per_iter);
        push(&mut rows, "serve/idle-32x256x256/activity-tracked", &sparse,
             steps_per_iter);
        assert!(
            skipped_after > skipped_before,
            "idle-fleet sparse ticks must skip tiles \
             ({skipped_before} -> {skipped_after})"
        );
        let idle_speedup = dense.median / sparse.median;
        println!(
            "  speedup: activity-tracked idle ticks are {idle_speedup:.1}x \
             vs dense ({} tiles skipped during the sparse leg)",
            skipped_after - skipped_before
        );
        if idle_speedup <= 1.0 {
            if soft() {
                println!(
                    "  WARN (soft mode): no CPU drop on the idle fleet \
                     ({idle_speedup:.2}x)"
                );
            } else {
                assert!(
                    idle_speedup > 1.0,
                    "settled sessions must step cheaper under activity \
                     tracking (got {idle_speedup:.2}x)"
                );
            }
        }
    }

    // --------------------------------------------- overload scenario
    // Drive a deliberately tiny queue past max_pending and check the
    // backpressure accounting end to end: the 503 counter, the
    // queue-depth high-water mark and the request-wait histogram must
    // all agree with what we actually submitted. These asserts are
    // correctness, not performance — they stay hard even under --soft.
    {
        header("serve — overload: 32 submissions into max_pending=16");
        let small = ServeConfig {
            max_sessions: 16,
            max_batch: 4,
            max_pending: 16,
            tick_window: Duration::ZERO,
            seed: 7,
            ..ServeConfig::default()
        };
        let c = Coalescer::new(&small);
        let spec = ProgramSpec::Eca { rule: 110, width: 256 };
        let ids = sessions(&c, &spec, 8);

        let (tx, rx) = channel();
        let (mut accepted, mut rejected) = (0usize, 0usize);
        for _round in 0..4 {
            for &id in &ids {
                match c.submit(StepRequest::new(id, 1, tx.clone())) {
                    Ok(()) => accepted += 1,
                    Err(_) => rejected += 1,
                }
            }
        }
        drop(tx);
        assert_eq!(accepted, 16, "max_pending=16 admits exactly 16");
        assert_eq!(rejected, 16, "the other 16 submissions bounce");

        let mut served = 0;
        let mut ticks = 0;
        while served < accepted {
            served += c.tick();
            ticks += 1;
            assert!(ticks <= 64, "overload drain did not converge");
        }
        for _ in 0..accepted {
            rx.recv().expect("reply").expect("step ok");
        }

        let stats = c.stats();
        assert_eq!(
            stats.rejected.load(Ordering::Relaxed),
            rejected as u64,
            "503 counter must match the bounced submissions"
        );
        assert_eq!(
            stats.queue_depth().high_water(),
            16,
            "queue-depth high-water mark must reach max_pending"
        );
        assert_eq!(stats.queue_depth().get(), 0, "queue drains to empty");
        let wait = stats.wait().snapshot();
        assert_eq!(
            wait.count, accepted as u64,
            "every accepted request records a wait sample"
        );
        assert!(
            wait.quantile(0.99) >= wait.quantile(0.50),
            "wait percentiles must be monotone"
        );
        assert!(
            stats.deferred.load(Ordering::Relaxed) > 0,
            "re-stepping the same sessions must defer some requests"
        );
        let batch = stats.batch_size().snapshot();
        assert!(
            batch.max <= 4,
            "no batch may exceed max_batch=4 (got {})",
            batch.max
        );
        println!(
            "  overload OK: {accepted} served over {ticks} ticks, \
             {rejected} rejected, wait p50 {:.1}us p99 {:.1}us, \
             high-water {}",
            wait.quantile(0.50) / 1e3,
            wait.quantile(0.99) / 1e3,
            stats.queue_depth().high_water()
        );
    }

    // -------------------------------- fleet: eviction under a RAM cap
    // The checkpoint/LRU acceptance row: 32 Life sessions through a
    // working-set cap of 8. Total sessions exceed the cap 4x while
    // resident RAM stays bounded by it; stepping a rotating window
    // forces evict/rehydrate churn through the on-disk store, measured
    // against the same window pattern with everything resident.
    {
        let (total, cap, size) = (32usize, 8usize, 128usize);
        header(&format!(
            "serve — fleet: {total} Life {size}x{size} sessions through a \
             working-set cap of {cap} (evict/rehydrate vs all-resident)"
        ));
        let dir = std::env::temp_dir()
            .join(format!("cax-bench-fleet-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = ProgramSpec::Life { height: size, width: size };

        let capped_cfg = ServeConfig {
            max_sessions: cap,
            max_batch: 64,
            max_pending: 4096,
            tick_window: Duration::ZERO,
            seed: 11,
            state_dir: Some(dir.clone()),
            ..ServeConfig::default()
        };
        let capped = Coalescer::try_new(&capped_cfg)
            .expect("fleet state dir opens");
        let ids = sessions(&capped, &spec, total);

        let resident = ServeConfig {
            max_sessions: total,
            seed: 11,
            ..capped_cfg.clone()
        };
        let resident = Coalescer::new(&resident);
        let resident_ids = sessions(&resident, &spec, total);

        // Rotating window: each round touches the `cap` sessions the
        // previous round evicted, so every round rehydrates a full
        // window from disk and spills the previous one.
        let windows: Vec<Vec<u64>> =
            ids.chunks(cap).map(|w| w.to_vec()).collect();
        let churn = bench(warm, iters.min(3), || {
            for w in &windows {
                coalesced_round(&capped, w, 1);
            }
        });
        let res_windows: Vec<Vec<u64>> =
            resident_ids.chunks(cap).map(|w| w.to_vec()).collect();
        let warm_arm = bench(warm, iters.min(3), || {
            for w in &res_windows {
                coalesced_round(&resident, w, 1);
            }
        });
        let steps_per_iter = total as f64;
        push(&mut rows, "serve/fleet-32over8-life-128/evict-rehydrate",
             &churn, steps_per_iter);
        push(&mut rows, "serve/fleet-32over8-life-128/all-resident",
             &warm_arm, steps_per_iter);

        // Correctness asserts — hard even under --soft: the cap is a
        // real RAM bound, and the churn actually went through disk.
        let (in_ram, bytes, sessions_total) = {
            let reg = capped.registry().lock().unwrap();
            (reg.len(), reg.resident_bytes(), reg.total_sessions())
        };
        let all_bytes =
            resident.registry().lock().unwrap().resident_bytes();
        assert_eq!(sessions_total, total,
                   "every created session stays addressable");
        assert!(in_ram <= cap,
                "resident count {in_ram} exceeds the cap {cap}");
        assert!(
            bytes * total <= all_bytes * cap,
            "resident bytes {bytes} exceed the working-set fraction \
             ({cap}/{total} of {all_bytes})"
        );
        let evictions = capped.stats().evictions().get();
        let rehydrations = capped.stats().rehydrations().get();
        assert!(evictions > 0 && rehydrations > 0,
                "the churn arm must hit the store \
                 ({evictions} evictions, {rehydrations} rehydrations)");
        println!(
            "  cap holds: {in_ram}/{total} resident ({bytes} bytes, \
             cap fraction {} bytes), {evictions} evictions, \
             {rehydrations} rehydrations; churn vs all-resident: {:.1}x",
            all_bytes * cap / total,
            churn.median / warm_arm.median
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ----------------------------------- streaming: publish overhead
    // SSE delivery rides the tick: the hub formats one frame per
    // stepped session and try_sends it to each subscriber, never
    // blocking the scheduler. Measure the coalesced round with and
    // without a (deliberately unread) subscriber — the bounded queue
    // fills and the publisher keeps dropping instead of stalling.
    {
        let (n, size) = (16usize, 128usize);
        header(&format!(
            "serve — streaming: {n} Life {size}x{size} sessions, frame \
             publish off vs on (slow subscriber)"
        ));
        let spec = ProgramSpec::Life { height: size, width: size };
        let ids = sessions(&coalescer, &spec, n);
        let quiet = bench(warm, iters.min(3), || {
            for _ in 0..rounds {
                coalesced_round(&coalescer, &ids, 1);
            }
        });
        // One never-read subscriber per session: after SUBSCRIBER_QUEUE
        // frames each queue is full and every further publish drops.
        // Prime past the queue bound first so the measured arm is the
        // steady slow-client state (try_send -> drop, every tick).
        let subs: Vec<_> =
            ids.iter().map(|&id| coalescer.hub().subscribe(id)).collect();
        let frames_before = coalescer.stats().stream_frames().get();
        for _ in 0..12 {
            coalesced_round(&coalescer, &ids, 1);
        }
        let streaming = bench(warm, iters.min(3), || {
            for _ in 0..rounds {
                coalesced_round(&coalescer, &ids, 1);
            }
        });
        let frames = coalescer.stats().stream_frames().get()
            - frames_before;
        let dropped = coalescer.stats().stream_dropped().get();
        push(&mut rows, "serve/stream-16x128x128/no-subscribers", &quiet,
             (n * rounds) as f64);
        push(&mut rows, "serve/stream-16x128x128/slow-subscriber",
             &streaming, (n * rounds) as f64);
        assert!(frames > 0, "subscribed ticks must deliver frames");
        assert!(
            dropped > 0,
            "a never-read subscriber must overflow its bounded queue \
             (frames {frames}, dropped {dropped})"
        );
        println!(
            "  streaming tick overhead: {:.1}% ({frames} frames \
             delivered, {dropped} dropped on the full queue — the \
             scheduler never blocked)",
            (streaming.median / quiet.median - 1.0) * 100.0
        );
        for ((token, _rx), &id) in subs.iter().zip(&ids) {
            coalescer.hub().unsubscribe(id, *token);
        }
    }

    let out = std::path::Path::new("BENCH_serve.json");
    finish("serve_load", &rows, out);

    if soft() {
        if speedup < 5.0 {
            println!(
                "WARN (soft mode): speedup {speedup:.2}x below the 5x \
                 acceptance anchor"
            );
        } else {
            println!("acceptance anchor OK: {speedup:.1}x >= 5x");
        }
    } else {
        assert!(
            speedup >= 5.0,
            "acceptance anchor: coalesced Life sessions must be >= 5x solo \
             (got {speedup:.2}x)"
        );
        println!("acceptance anchor OK: {speedup:.1}x >= 5x");
    }
}
