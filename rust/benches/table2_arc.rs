//! E5 — Table 2 benchmark: end-to-end 1D-ARC pipeline cost.
//!
//! Times the three phases the Table-2 harness is built from — dataset
//! generation, per-task training, exact-match evaluation — so the
//! `cax-tables table2` wall-clock budget is understood, and reports a
//! mini-Table-2 (3 representative tasks) as a smoke of the full run.

use cax::coordinator::trainer::TrainCfg;
use cax::coordinator::{evaluator, experiments};
use cax::datasets::arc1d::Task;

mod bench_util;
use bench_util::{bench, engine, header, quick, row};

fn main() {
    let engine = engine();
    let (train_steps, train_n, test_n) =
        if quick() { (40, 48, 16) } else { (120, 96, 32) };
    let tasks = [Task::Move1, Task::Denoise, Task::Fill];

    header("Table 2 — dataset generation throughput");
    {
        let stats = bench(1, 5, || {
            for &t in Task::ALL.iter() {
                let _ = t.dataset(32, 64, 16, 7);
            }
        });
        row("arc1d/generate (18 tasks x 80 ex)", &stats,
            18.0 * 80.0);
    }

    header(&format!(
        "Table 2 — per-task train ({train_steps} steps) + eval pipeline"
    ));
    let mut printed: Vec<(Task, f64, f64)> = vec![];
    for &task in &tasks {
        let (train_set, test_set) = experiments::arc_split(
            &engine, task, train_n, test_n, 7,
        )
        .unwrap();
        let cfg = TrainCfg { steps: train_steps, seed: 7, log_every: 0,
                             out_dir: None };
        let mut acc = 0.0;
        let t_train = bench(0, 1, || {
            let run = experiments::train_arc(&engine, &cfg, task, &train_set)
                .unwrap();
            acc = evaluator::arc_accuracy(&engine, &run.state.params,
                                          &test_set)
                .unwrap();
        });
        row(&format!("arc/train+eval/{}", task.name()), &t_train,
            train_steps as f64);
        printed.push((task, acc, t_train.median));
    }

    header("mini Table 2 (3 tasks, short training)");
    println!("{:<28} {:>7} {:>7} {:>9}", "Task", "GPT-4", "NCA", "paper-NCA");
    for (task, acc, _) in &printed {
        println!(
            "{:<28} {:>6.0}% {:>6.1}% {:>8.0}%",
            task.name(),
            task.gpt4_accuracy(),
            100.0 * acc,
            task.paper_nca_accuracy()
        );
    }
    println!("(full 18-task table: `cax-tables table2`)");
}
