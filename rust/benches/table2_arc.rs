//! E5 — Table 2 benchmark: 1D-ARC pipeline cost.
//!
//! Native arm (default features, always runs): dataset-generation
//! throughput, then the native `arc_train_step` (BPTT over the 1D cell,
//! multi-threaded across the batch) vs the same math forced onto one
//! worker thread, plus `arc_eval` rollout throughput. Emits
//! `BENCH_arc_native.json` with the native-vs-1-thread comparison.
//!
//! PJRT arm (`--features pjrt` + artifacts): the original end-to-end
//! phase timing of the artifact-backed Table-2 harness — per-task
//! train + exact-match eval over three representative tasks.

use cax::backend::{NativeTrainBackend, ProgramBackend, Value};
use cax::coordinator::trainer::TrainState;
use cax::datasets::arc1d::{one_hot_batch, Task};
use cax::metrics::BenchRow;
use cax::tensor::Tensor;
use cax::util::rng::Rng;

mod bench_util;
use bench_util::{bench, finish, header, quick, row};

/// One native ARC train step: execute + fold (params, m, v) back.
fn native_step(backend: &NativeTrainBackend, st: &mut TrainState,
               ins: &Tensor, tgts: &Tensor, seed: u32) {
    let out = backend
        .execute(
            "arc_train_step",
            &[
                Value::F32(st.params.clone()),
                Value::F32(st.m.clone()),
                Value::F32(st.v.clone()),
                Value::I32(st.step),
                Value::F32(ins.clone()),
                Value::F32(tgts.clone()),
                Value::U32(seed),
            ],
        )
        .unwrap();
    let mut it = out.into_iter();
    st.params = it.next().unwrap();
    st.m = it.next().unwrap();
    st.v = it.next().unwrap();
    st.step += 1;
}

/// A one-hot (inputs, targets) batch of one task at the spec geometry.
fn task_batch(backend: &NativeTrainBackend, task: Task, seed: u64)
              -> (Tensor, Tensor) {
    let spec = backend.arc_spec();
    let mut rng = Rng::new(seed);
    let examples: Vec<_> = (0..spec.batch)
        .map(|_| task.generate(spec.width, &mut rng))
        .collect();
    let ins: Vec<&[u8]> =
        examples.iter().map(|e| e.input.as_slice()).collect();
    let tgts: Vec<&[u8]> =
        examples.iter().map(|e| e.target.as_slice()).collect();
    (one_hot_batch(&ins, spec.width), one_hot_batch(&tgts, spec.width))
}

fn main() {
    let mut rows: Vec<BenchRow> = vec![];
    let (warm, iters) = if quick() { (1, 3) } else { (2, 10) };

    header("Table 2 — dataset generation throughput");
    {
        let stats = bench(1, 5, || {
            for &t in Task::ALL.iter() {
                let _ = t.dataset(32, 64, 16, 7);
            }
        });
        row("arc1d/generate (18 tasks x 80 ex)", &stats, 18.0 * 80.0);
    }

    // ------------------------------------------------- native vs naive
    let full = NativeTrainBackend::new();
    let naive = NativeTrainBackend::with_threads(1);
    let spec = full.arc_spec().clone();
    let (ins, tgts) = task_batch(&full, Task::Denoise, 42);

    header(&format!(
        "Table 2 — ARC train step, native BPTT (batch {}, width {}, \
         {} channels, hidden {}, {}..={} rollout steps)",
        spec.batch, spec.width, spec.channels(), spec.hidden,
        spec.rollout_min, spec.rollout_max
    ));

    let mut st = TrainState::from_blob(&full, "arc_params").unwrap();
    let mut seed = 0u32;
    let threaded = bench(warm, iters, || {
        seed = seed.wrapping_add(1);
        native_step(&full, &mut st, &ins, &tgts, seed);
    });

    let mut st1 = TrainState::from_blob(&naive, "arc_params").unwrap();
    let mut seed1 = 0u32;
    let single = bench(warm.min(1), iters, || {
        seed1 = seed1.wrapping_add(1);
        native_step(&naive, &mut st1, &ins, &tgts, seed1);
    });

    let threaded_label =
        format!("arc-train/native-bptt ({} threads)", full.threads());
    row(&threaded_label, &threaded, 1.0);
    row("arc-train/naive-1thread", &single, 1.0);
    println!(
        "  native speedup: {:.2}x train-steps/s over the single-thread \
         baseline ({} worker threads)",
        single.median / threaded.median,
        full.threads()
    );
    rows.push(BenchRow {
        label: threaded_label,
        stats: threaded.clone(),
        items_per_iter: 1.0,
    });
    rows.push(BenchRow {
        label: "arc-train/naive-1thread".to_string(),
        stats: single.clone(),
        items_per_iter: 1.0,
    });

    // Eval rollouts: the exact-match scorer's inner program.
    let eval = bench(warm, iters, || {
        let out = full
            .execute("arc_eval",
                     &[Value::F32(st.params.clone()),
                       Value::F32(ins.clone())])
            .unwrap();
        assert_eq!(out[0].shape()[0], spec.batch);
    });
    row("arc-eval/native rollout", &eval, spec.batch as f64);
    rows.push(BenchRow {
        label: "arc-eval/native".to_string(),
        stats: eval,
        items_per_iter: spec.batch as f64,
    });

    let out = std::path::Path::new("BENCH_arc_native.json");
    finish("table2_arc_native", &rows, out);

    // ------------------------------------- artifact arm (pjrt builds)
    #[cfg(feature = "pjrt")]
    pjrt_arm();
}

/// End-to-end artifact-backed pipeline; skipped when artifacts are
/// absent.
#[cfg(feature = "pjrt")]
fn pjrt_arm() {
    use cax::coordinator::trainer::TrainCfg;
    use cax::coordinator::{evaluator, experiments};

    let Ok(engine) = cax::runtime::Engine::load(&bench_util::artifacts_dir())
    else {
        println!("\n(pjrt enabled but no artifacts found; skipping the \
                  fused XLA arm)");
        return;
    };
    let (train_steps, train_n, test_n) =
        if quick() { (40, 48, 16) } else { (120, 96, 32) };
    let tasks = [Task::Move1, Task::Denoise, Task::Fill];

    header(&format!(
        "Table 2 — per-task train ({train_steps} steps) + eval pipeline \
         (pjrt)"
    ));
    let mut printed: Vec<(Task, f64, f64)> = vec![];
    for &task in &tasks {
        let (train_set, test_set) = experiments::arc_split(
            &engine, task, train_n, test_n, 7,
        )
        .unwrap();
        let cfg = TrainCfg { steps: train_steps, seed: 7, log_every: 0,
                             out_dir: None };
        let mut acc = 0.0;
        let t_train = bench(0, 1, || {
            let run = experiments::train_arc(&engine, &cfg, task, &train_set)
                .unwrap();
            acc = evaluator::arc_accuracy(&engine, &run.state.params,
                                          &test_set)
                .unwrap();
        });
        row(&format!("arc/train+eval/{}", task.name()), &t_train,
            train_steps as f64);
        printed.push((task, acc, t_train.median));
    }

    header("mini Table 2 (3 tasks, short training)");
    println!("{:<28} {:>7} {:>7} {:>9}", "Task", "GPT-4", "NCA", "paper-NCA");
    for (task, acc, _) in &printed {
        println!(
            "{:<28} {:>6.0}% {:>6.1}% {:>8.0}%",
            task.name(),
            task.gpt4_accuracy(),
            100.0 * acc,
            task.paper_nca_accuracy()
        );
    }
    println!("(full 18-task table: `cax eval arc --task all`)");
}
