//! E3 — Figure 3 (right): NCA training speed on self-classifying MNIST.
//!
//! Native arm (default features, always runs): the hand-rolled BPTT +
//! Adam train step of `cax::backend::native::train`, multi-threaded
//! across the batch, vs the same math forced onto one worker thread
//! (the naive host baseline). Emits `BENCH_nca_train_native.json` with
//! the native-vs-naive train-steps/s comparison.
//!
//! PJRT arm (`--features pjrt` + artifacts): ONE fused XLA program per
//! training step (rollout + BPTT + Adam in-graph) vs host-driven
//! per-step dispatch — the cost structure the paper attributes to the
//! official TensorFlow implementation. Paper: 1.5x speedup.

use cax::backend::{NativeTrainBackend, ProgramBackend, Value};
use cax::coordinator::trainer::TrainState;
use cax::datasets::mnist::{self, MnistConfig};
use cax::metrics::BenchRow;
use cax::tensor::Tensor;

mod bench_util;
use bench_util::{bench, finish, header, quick, row};

/// One native train step: execute + fold the updated (params, m, v)
/// back into the state.
fn native_step(backend: &NativeTrainBackend, st: &mut TrainState,
               images: &Tensor, labels: &Tensor, seed: u32) {
    let out = backend
        .execute(
            "mnist_train_step",
            &[
                Value::F32(st.params.clone()),
                Value::F32(st.m.clone()),
                Value::F32(st.v.clone()),
                Value::I32(st.step),
                Value::F32(images.clone()),
                Value::F32(labels.clone()),
                Value::U32(seed),
            ],
        )
        .unwrap();
    let mut it = out.into_iter();
    st.params = it.next().unwrap();
    st.m = it.next().unwrap();
    st.v = it.next().unwrap();
    st.step += 1;
}

fn main() {
    let mut rows: Vec<BenchRow> = vec![];
    let (warm, iters) = if quick() { (1, 3) } else { (2, 10) };

    // ------------------------------------------------- native vs naive
    let full = NativeTrainBackend::new();
    let naive = NativeTrainBackend::with_threads(1);
    let spec = full.mnist_spec().clone();
    let digits = mnist::dataset(
        spec.batch,
        &MnistConfig::for_grid(spec.height, spec.width),
        42,
    );
    let refs: Vec<&mnist::Digit> = digits.iter().collect();
    let images = mnist::batch_images(&refs);
    let labels = mnist::batch_labels(&refs);

    header(&format!(
        "Fig. 3 right — MNIST NCA train step, native BPTT (batch {}, \
         {}x{}x{} state, hidden {}, {}..={} rollout steps)",
        spec.batch, spec.height, spec.width, spec.channels, spec.hidden,
        spec.rollout_min, spec.rollout_max
    ));

    let mut st = TrainState::from_blob(&full, "mnist_params").unwrap();
    let mut seed = 0u32;
    let threaded = bench(warm, iters, || {
        seed = seed.wrapping_add(1);
        native_step(&full, &mut st, &images, &labels, seed);
    });

    let mut st1 = TrainState::from_blob(&naive, "mnist_params").unwrap();
    let mut seed1 = 0u32;
    let single = bench(warm.min(1), iters, || {
        seed1 = seed1.wrapping_add(1);
        native_step(&naive, &mut st1, &images, &labels, seed1);
    });

    let threaded_label =
        format!("nca-train/native-bptt ({} threads)", full.threads());
    row(&threaded_label, &threaded, 1.0);
    row("nca-train/naive-1thread", &single, 1.0);
    println!(
        "  native speedup: {:.2}x train-steps/s over the single-thread \
         baseline ({} worker threads)",
        single.median / threaded.median,
        full.threads()
    );
    rows.push(BenchRow {
        label: threaded_label,
        stats: threaded.clone(),
        items_per_iter: 1.0,
    });
    rows.push(BenchRow {
        label: "nca-train/naive-1thread".to_string(),
        stats: single.clone(),
        items_per_iter: 1.0,
    });

    let out = std::path::Path::new("BENCH_nca_train_native.json");
    finish("fig3_nca_train_native", &rows, out);

    // ------------------------------------- fused XLA arm (pjrt builds)
    #[cfg(feature = "pjrt")]
    pjrt_arm(warm, iters);
}

/// Fused-vs-stepwise XLA comparison; skipped when artifacts are absent.
#[cfg(feature = "pjrt")]
fn pjrt_arm(warm: usize, iters: usize) {
    use cax::coordinator::stepwise::mnist_stepwise_train_step;

    let Ok(engine) = cax::runtime::Engine::load(&bench_util::artifacts_dir())
    else {
        println!("\n(pjrt enabled but no artifacts found; skipping the \
                  fused XLA arm)");
        return;
    };
    let info = engine.manifest().artifact("mnist_train_step").unwrap();
    let spec = &info.inputs[4];
    let (b, h, w) = (spec.shape[0], spec.shape[1], spec.shape[2]);
    let rollout_steps = info.meta_usize("steps").unwrap();
    let digits = mnist::dataset(b, &MnistConfig::for_grid(h, w), 42);
    let refs: Vec<&mnist::Digit> = digits.iter().collect();
    let images = mnist::batch_images(&refs);
    let labels = mnist::batch_labels(&refs);

    header(&format!(
        "Fig. 3 right — MNIST NCA train step, fused XLA (batch {b}, \
         {h}x{w}, {rollout_steps} rollout steps + BPTT)"
    ));

    // Fused: one artifact execution per train step.
    let mut st = TrainState::from_blob(&engine, "mnist_params").unwrap();
    let mut seed = 0u32;
    let fused = bench(warm, iters, || {
        seed = seed.wrapping_add(1);
        let out = engine
            .execute(
                "mnist_train_step",
                &[
                    Value::F32(st.params.clone()),
                    Value::F32(st.m.clone()),
                    Value::F32(st.v.clone()),
                    Value::I32(st.step),
                    Value::F32(images.clone()),
                    Value::F32(labels.clone()),
                    Value::U32(seed),
                ],
            )
            .unwrap();
        let mut it = out.into_iter();
        st.params = it.next().unwrap();
        st.m = it.next().unwrap();
        st.v = it.next().unwrap();
        st.step += 1;
    });

    // Stepwise: 2T+1 artifact executions + host reductions per step.
    let mut st2 = TrainState::from_blob(&engine, "mnist_params").unwrap();
    let mut seed2 = 0u32;
    let stepwise = bench(warm.min(1), iters.min(6), || {
        seed2 = seed2.wrapping_add(1);
        mnist_stepwise_train_step(
            &engine, &mut st2.params, &mut st2.m, &mut st2.v, st2.step,
            &images, &labels, 1e-3, seed2,
        )
        .unwrap();
        st2.step += 1;
    });

    row("mnist-train/cax-fused (1 dispatch)", &fused, 1.0);
    row(
        &format!("mnist-train/stepwise ({} dispatches)",
                 2 * rollout_steps + 1),
        &stepwise,
        1.0,
    );
    println!(
        "  fused speedup: {:.2}x (paper reports 1.5x over the official \
         TensorFlow implementation)",
        stepwise.median / fused.median
    );
    let s = engine.stats();
    println!(
        "  engine totals: {} executions, {:.1}s execute, {:.1} MB out",
        s.executions,
        s.execute_secs,
        s.bytes_out as f64 / 1e6
    );
}
