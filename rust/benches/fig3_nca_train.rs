//! E3 — Figure 3 (right): NCA training speed on self-classifying MNIST.
//!
//! CAX path: ONE fused XLA program per training step (rollout + BPTT +
//! Adam in-graph). Baseline ("TF-proxy"): host-driven per-step dispatch —
//! T forward executions, T VJP executions, host Adam — the cost structure
//! the paper attributes to the official TensorFlow implementation.
//! Paper: 1.5x speedup.

use cax::coordinator::stepwise::mnist_stepwise_train_step;
use cax::coordinator::trainer::TrainState;
use cax::datasets::mnist::{self, MnistConfig};
use cax::runtime::Value;

mod bench_util;
use bench_util::{bench, engine, header, quick, row};

fn main() {
    let engine = engine();
    let info = engine.manifest().artifact("mnist_train_step").unwrap();
    let spec = &info.inputs[4];
    let (b, h, w) = (spec.shape[0], spec.shape[1], spec.shape[2]);
    let rollout_steps = info.meta_usize("steps").unwrap();
    let digits = mnist::dataset(b, &MnistConfig::for_grid(h, w), 42);
    let refs: Vec<&mnist::Digit> = digits.iter().collect();
    let images = mnist::batch_images(&refs);
    let labels = mnist::batch_labels(&refs);
    let (warm, iters) = if quick() { (1, 3) } else { (2, 12) };

    header(&format!(
        "Fig. 3 right — MNIST NCA train step (batch {b}, {h}x{w}, \
         {rollout_steps} rollout steps + BPTT)"
    ));

    // Fused: one artifact execution per train step.
    let mut st = TrainState::from_blob(&engine, "mnist_params").unwrap();
    let mut seed = 0u32;
    let fused = bench(warm, iters, || {
        seed = seed.wrapping_add(1);
        let out = engine
            .execute(
                "mnist_train_step",
                &[
                    Value::F32(st.params.clone()),
                    Value::F32(st.m.clone()),
                    Value::F32(st.v.clone()),
                    Value::I32(st.step),
                    Value::F32(images.clone()),
                    Value::F32(labels.clone()),
                    Value::U32(seed),
                ],
            )
            .unwrap();
        let mut it = out.into_iter();
        st.params = it.next().unwrap();
        st.m = it.next().unwrap();
        st.v = it.next().unwrap();
        st.step += 1;
    });

    // Stepwise: 2T+1 artifact executions + host reductions per train step.
    let mut st2 = TrainState::from_blob(&engine, "mnist_params").unwrap();
    let mut seed2 = 0u32;
    let stepwise = bench(warm.min(1), iters.min(6), || {
        seed2 = seed2.wrapping_add(1);
        mnist_stepwise_train_step(
            &engine, &mut st2.params, &mut st2.m, &mut st2.v, st2.step,
            &images, &labels, 1e-3, seed2,
        )
        .unwrap();
        st2.step += 1;
    });

    row("mnist-train/cax-fused (1 dispatch)", &fused, 1.0);
    row(
        &format!("mnist-train/stepwise ({} dispatches)",
                 2 * rollout_steps + 1),
        &stepwise,
        1.0,
    );
    println!(
        "  fused speedup: {:.2}x (paper reports 1.5x over the official \
         TensorFlow implementation)",
        stepwise.median / fused.median
    );
    let s = engine.stats();
    println!(
        "  engine totals: {} executions, {:.1}s execute, {:.1} MB out",
        s.executions,
        s.execute_secs,
        s.bytes_out as f64 / 1e6
    );
}
