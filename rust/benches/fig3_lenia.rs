//! Fig. 3 extended to spectral Lenia: a radius sweep pitting the tiled
//! sparse-tap kernel against the in-tree FFT kernel on a 256x256 board,
//! plus Bluestein (non-power-of-two) and multi-kernel rows. Both arms
//! run batch-parallel over the same worker pool, so the rows isolate
//! the per-cell kernel cost — exactly the quantity the
//! `select_path` crossover heuristic models (sparse ~ pi r^2 taps/cell,
//! spectral ~ log2 hw butterflies/cell).
//!
//! Emits `BENCH_lenia_fft.json`. Acceptance anchor: the FFT kernel is
//! >= 5x the sparse-tap kernel at radius >= 32 on this very board.
//!
//! Run: cargo bench --bench fig3_lenia [-- --quick]

use cax::automata::lenia::{LeniaParams, LeniaWorld};
use cax::backend::native::lenia::{
    select_path, LeniaFft, LeniaKernel, LeniaPath,
};
use cax::backend::WorkerPool;
use cax::metrics::BenchRow;
use cax::tensor::Tensor;
use cax::util::rng::Rng;

mod bench_util;
use bench_util::{bench, finish, header, push, quick, soft};

/// Batch of soup boards as one `[B, H, W]` buffer.
fn soup(b: usize, size: usize, rng: &mut Rng) -> Tensor {
    Tensor::new(vec![b, size, size], rng.vec_f32(b * size * size)).unwrap()
}

fn main() {
    let pool = WorkerPool::new();
    let mut rng = Rng::new(42);
    let mut rows: Vec<BenchRow> = vec![];
    let (warm, iters) = if quick() { (0, 2) } else { (1, 4) };
    let (b, size) = if quick() { (2, 128) } else { (4, 256) };
    let steps = if quick() { 2 } else { 4 };
    println!("worker pool: {} threads", pool.threads());

    let radii: &[usize] =
        if quick() { &[8, 32] } else { &[4, 8, 16, 32, 64] };
    // (scalar sparse median, fft median) at r=32 — the acceptance
    // anchor compares the spectral kernel against the *scalar* sparse
    // baseline, so the 5x target keeps its meaning whether or not the
    // dispatching sparse arm takes the AVX2 path on this host.
    let mut at32 = (0.0f64, 0.0f64);
    let mut simd8 = (0.0f64, 0.0f64); // (scalar, dispatch) at r=8

    for &radius in radii {
        let params = LeniaParams { radius, ..Default::default() };
        header(&format!(
            "Lenia radius sweep — r={radius} ({b}x{size}x{size}, {steps} \
             steps; crossover picks {}, simd {})",
            select_path(radius, size, size).name(),
            cax::backend::native::simd::status()
        ));
        let state = soup(b, size, &mut rng);
        let updates = (b * size * size * steps) as f64;

        let sparse_kernel = LeniaKernel::new(params);
        let sparse = bench(warm, iters, || {
            let mut data = state.data().to_vec();
            pool.for_each_chunk(&mut data, size * size, |_, board| {
                let mut scratch = vec![0.0f32; size * size];
                sparse_kernel.rollout(board, &mut scratch, size, size,
                                      steps);
            });
        });
        // Forced-scalar sparse arm at the SIMD-comparison radius and
        // the acceptance radius (everywhere would double sweep cost).
        let sparse_scalar = (radius == 8 || radius == 32).then(|| {
            bench(warm, iters, || {
                let mut data = state.data().to_vec();
                pool.for_each_chunk(&mut data, size * size, |_, board| {
                    let mut scratch = vec![0.0f32; size * size];
                    for _ in 0..steps {
                        sparse_kernel
                            .step_scalar(board, &mut scratch, size, size);
                        board.copy_from_slice(&scratch);
                    }
                });
            })
        });
        let fft_kernel = LeniaFft::new(params, size, size).unwrap();
        let fft = bench(warm, iters, || {
            let mut data = state.data().to_vec();
            pool.for_each_chunk(&mut data, size * size, |_, board| {
                fft_kernel.rollout(board, steps);
            });
        });
        push(&mut rows, &format!("lenia/r{radius}/sparse-tap"), &sparse,
             updates);
        if let Some(scalar) = &sparse_scalar {
            push(&mut rows, &format!("lenia/r{radius}/sparse-scalar"),
                 scalar, updates);
            println!("  speedup: dispatching sparse-tap is {:.1}x vs \
                      forced-scalar", scalar.median / sparse.median);
        }
        push(&mut rows, &format!("lenia/r{radius}/fft"), &fft, updates);
        let speedup = sparse.median / fft.median;
        println!("  speedup: fft is {speedup:.1}x vs sparse-tap");
        if radius == 8 {
            if let Some(scalar) = &sparse_scalar {
                simd8 = (scalar.median, sparse.median);
            }
        }
        if radius == 32 {
            let baseline = sparse_scalar
                .as_ref()
                .map(|s| s.median)
                .unwrap_or(sparse.median);
            at32 = (baseline, fft.median);
        }
    }

    // Bluestein row: a non-power-of-two board at a spectral radius.
    {
        let radius = 32;
        let nsize = if quick() { 100 } else { 250 };
        let params = LeniaParams { radius, ..Default::default() };
        header(&format!(
            "Lenia Bluestein axes — r={radius} ({b}x{nsize}x{nsize}, \
             {steps} steps)"
        ));
        let state = soup(b, nsize, &mut rng);
        let updates = (b * nsize * nsize * steps) as f64;
        let fft_kernel = LeniaFft::new(params, nsize, nsize).unwrap();
        assert!(fft_kernel.is_bluestein());
        let fft = bench(warm, iters, || {
            let mut data = state.data().to_vec();
            pool.for_each_chunk(&mut data, nsize * nsize, |_, board| {
                fft_kernel.rollout(board, steps);
            });
        });
        push(&mut rows, &format!("lenia/r{radius}/bluestein{nsize}"),
             &fft, updates);
    }

    // Multi-kernel world row: 3 kernels on 2 channels, spectral only.
    {
        let kernels = 3;
        let radius = if quick() { 16 } else { 32 };
        let world = LeniaWorld::demo(kernels, radius);
        header(&format!(
            "Lenia multi-kernel world — K={kernels}, C={}, r={radius} \
             ({b}x{size}x{size}, {steps} steps)",
            world.channels
        ));
        let c = world.channels;
        let state =
            Tensor::new(vec![b, c, size, size],
                        rng.vec_f32(b * c * size * size))
                .unwrap();
        let updates = (b * c * size * size * steps) as f64;
        let plan = LeniaFft::for_world(world, size, size).unwrap();
        let fft = bench(warm, iters, || {
            let mut data = state.data().to_vec();
            pool.for_each_chunk(&mut data, c * size * size, |_, board| {
                plan.rollout(board, steps);
            });
        });
        push(&mut rows, &format!("lenia/multi-k{kernels}-r{radius}/fft"),
             &fft, updates);
    }

    if at32.1 > 0.0 {
        let speedup = at32.0 / at32.1;
        println!(
            "\nacceptance: fft vs scalar sparse-tap at r=32 on \
             {size}x{size}: {speedup:.1}x (target >= 5x)"
        );
        assert!(
            quick() || speedup >= 5.0,
            "spectral Lenia below the 5x acceptance anchor: {speedup:.2}x"
        );
    }
    // SIMD acceptance at r=8 (the sparse regime): the AVX2 sparse-tap
    // kernel is >= 2x its forced-scalar form when avx2 dispatched.
    if simd8.1 > 0.0 && cax::backend::native::simd::active() && !quick() {
        let speedup = simd8.0 / simd8.1;
        println!(
            "acceptance: simd vs scalar sparse-tap at r=8: {speedup:.1}x \
             (target >= 2x)"
        );
        if speedup < 2.0 {
            assert!(soft(),
                    "SIMD sparse-tap below the 2x target: {speedup:.2}x");
            println!("  (soft mode: not failing on the 2x target)");
        }
    }
    // Verify the crossover constant tells the truth on this machine:
    // the selected path must be the measured-faster one at the sweep's
    // extremes (r=4 sparse, r=32+ fft on a 256 board).
    assert_eq!(select_path(4, size, size), LeniaPath::SparseTap);
    assert_eq!(select_path(64, size, size), LeniaPath::Fft);

    let out = std::path::Path::new("BENCH_lenia_fft.json");
    finish("fig3_lenia", &rows, out);
}
