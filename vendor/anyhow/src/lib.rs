//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build must be hermetic (no network, no registry), so the error
//! substrate the whole crate uses lives in-tree. It reproduces the subset
//! of `anyhow`'s API this workspace relies on:
//!
//! - [`Error`]: an opaque error carrying a context chain (outermost last).
//! - [`Result`]: `Result<T, Error>` with a defaulted error type.
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` *and*
//!   `Option`.
//! - `anyhow!`, `bail!`, `ensure!` macros (format-string forms).
//! - `From<E>` for every `E: std::error::Error + Send + Sync + 'static`,
//!   so `?` converts foreign errors, preserving their `source()` chain.
//!
//! `Display` prints the outermost message; the alternate form (`{:#}`)
//! prints the full chain joined with `": "`, matching anyhow's behaviour
//! that the CLI error reporter depends on.

use std::fmt::{self, Debug, Display};

/// An error with a chain of context messages.
///
/// Internally `chain[0]` is the root cause and the last element the
/// outermost context. Like `anyhow::Error`, this type deliberately does
/// NOT implement `std::error::Error` — that is what makes the blanket
/// `From<E: std::error::Error>` impl coherent.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context (the new outermost).
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    /// The messages outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, msg) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.chain.last().expect("chain is never empty"))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.last().expect("chain is never empty"))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, msg) in self.chain.iter().rev().skip(1).enumerate() {
                write!(f, "\n    {i}: {msg}")?;
            }
        }
        Ok(())
    }
}

/// `anyhow`-style result alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut sources = Vec::new();
        let mut src = std::error::Error::source(&err);
        while let Some(s) = src {
            sources.push(s.to_string());
            src = s.source();
        }
        // Root cause first, the error itself as the outermost message.
        sources.reverse();
        sources.push(err.to_string());
        Error { chain: sources }
    }
}

mod private {
    use std::fmt::Display;

    /// Sealed conversion into [`crate::Error`]. Implemented for every
    /// std error *and* for `Error` itself — coherent because `Error`
    /// does not implement `std::error::Error`.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }

    /// Shared bound for context messages.
    pub trait Msg: Display + Send + Sync + 'static {}
    impl<T: Display + Send + Sync + 'static> Msg for T {}
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: private::Msg>(self, context: C) -> Result<T>;

    /// Attach a lazily-built context message.
    fn with_context<C: private::Msg, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: private::Msg>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: private::Msg, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: private::Msg>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: private::Msg, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($args:tt)+) => {
        $crate::Error::msg(::std::format!($($args)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($args:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($args)+))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($args:tt)+) => {
        if !($cond) {
            $crate::bail!($($args)+);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outermost_alternate_full_chain() {
        let e = Error::msg("root").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("missing file"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").contains("missing file"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no value {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "no value 7");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).is_err());
        assert!(format!("{}", f(11).unwrap_err()).contains("too big"));
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }
}
