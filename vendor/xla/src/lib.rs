//! API-compatible stub of the `xla` PJRT bindings used by `cax`'s
//! `runtime::engine`.
//!
//! The offline build environment has no PJRT runtime, but the `pjrt`
//! cargo feature must still *compile*. This crate mirrors exactly the
//! type/function surface `engine.rs` touches; every entry point that
//! would need a real XLA runtime returns an error. Deployments with the
//! real `xla` crate available swap it in via a `[patch]` section or by
//! replacing this path dependency — no `cax` source changes needed.

use std::fmt;

/// Error type mirroring `xla::Error`'s role.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "no PJRT runtime in this build (in-tree `xla` stub); \
         link the real `xla` crate to enable the pjrt backend"
            .to_string(),
    )
}

/// Element types crossing the literal boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

/// Scalar types `Literal::scalar` accepts.
pub trait NativeType {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for u32 {}

/// Host-side literal (stub: holds nothing).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal { _private: () }
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client (stub). `cpu()` always fails: that is the single runtime
/// gate — nothing downstream can be reached without a client.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_loudly() {
        let err = PjRtClient::cpu().err().expect("stub must refuse");
        assert!(err.to_string().contains("stub"));
    }
}
