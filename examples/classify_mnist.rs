//! Self-classifying MNIST digits (Randazzo et al. 2020; paper Table 1 row
//! 7 and the Fig. 3-right benchmark subject): every ink cell must agree on
//! the digit's class purely through local message passing.
//!
//!   cargo run --release --features pjrt --example classify_mnist --
//!       [--steps N] [--seed S]
//!
//! Trains with the fused train-step artifact, then reports majority-vote
//! accuracy on held-out synthetic digits and shows a per-digit vote map.
//!
//! **pjrt-gated** (`required-features`): training runs natively via
//! `cax train mnist --backend native`, but the *vote-map evaluation*
//! here needs the `mnist_eval` rollout program, which only the artifact
//! backend serves today. See the examples table in `rust/README.md`.

use anyhow::{Context, Result};

use cax::coordinator::evaluator;
use cax::coordinator::experiments;
use cax::coordinator::trainer::TrainCfg;
use cax::datasets::mnist::{self, MnistConfig};
use cax::runtime::{Engine, Value};

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() -> Result<()> {
    let steps: usize =
        arg("--steps").map(|s| s.parse()).transpose()?.unwrap_or(600);
    let seed: u32 = arg("--seed").map(|s| s.parse()).transpose()?.unwrap_or(0);

    let artifacts = std::env::var("CAX_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let engine = Engine::load(std::path::Path::new(&artifacts))
        .context("run `make artifacts` first")?;

    println!("== self-classifying MNIST NCA: {steps} fused train steps ==");
    let cfg = TrainCfg { steps, seed, log_every: 50, out_dir: None };
    let run = experiments::train_mnist(&engine, &cfg)?;
    let (first, last) = run.history.window_means(20);
    println!("loss {first:.5} -> {last:.5}");

    // Held-out accuracy.
    let info = engine.manifest().artifact("mnist_eval")?;
    let (b, h, w) = (info.inputs[1].shape[0], info.inputs[1].shape[1],
                     info.inputs[1].shape[2]);
    let digits =
        mnist::dataset(100, &MnistConfig::for_grid(h, w), seed as u64 ^ 0xE);
    let refs: Vec<&mnist::Digit> = digits.iter().collect();
    let acc = evaluator::mnist_accuracy(&engine, &run.state.params, &refs,
                                        seed)?;
    println!("majority-vote accuracy on 100 held-out digits: {:.1}%",
             100.0 * acc);

    // Vote map for one batch: which class each ink cell votes for.
    let chunk: Vec<&mnist::Digit> = digits.iter().take(b).collect();
    let batch = mnist::batch_images(&chunk);
    let out = engine.execute(
        "mnist_eval",
        &[Value::F32(run.state.params.clone()), Value::F32(batch.clone()),
          Value::U32(seed)],
    )?;
    let logits = &out[0]; // [B, H, W, 10]
    for (i, d) in chunk.iter().enumerate() {
        println!("\ndigit {} — per-cell votes ('.' = no ink):", d.label);
        for y in 0..h {
            let mut line = String::with_capacity(w);
            for x in 0..w {
                if batch.at(&[i, y, x]) <= 0.1 {
                    line.push('.');
                    continue;
                }
                let mut best = 0;
                let mut best_v = f32::NEG_INFINITY;
                for cls in 0..10 {
                    let v = logits.at(&[i, y, x, cls]);
                    if v > best_v {
                        best_v = v;
                        best = cls;
                    }
                }
                line.push(char::from_digit(best as u32, 10).unwrap());
            }
            println!("  {line}");
        }
        if i >= 2 {
            break; // three digits are enough for the demo
        }
    }
    Ok(())
}
