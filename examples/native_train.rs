//! Native NCA training end to end: the hermetic growing-NCA run.
//!
//!   cargo run --release --example native_train [-- --quick]
//!
//! Trains the growing NCA with the App. B sample-pool recipe entirely
//! on `cax::backend::NativeTrainBackend` — hand-rolled BPTT, gradient
//! clipping, Adam and the lr schedule on the host, batch-parallel over
//! the worker pool; no artifacts, no XLA and no Python anywhere. The
//! trained cell is then rolled forward from the single seed cell
//! through the plain inference backend.

use anyhow::Result;

use cax::backend::native::nca::NcaModel;
use cax::backend::{Backend, CaProgram, NativeBackend, NativeTrainBackend};
use cax::coordinator::experiments;
use cax::coordinator::trainer::TrainCfg;
use cax::tensor::Tensor;
use cax::util::timer::Timer;

fn main() -> Result<()> {
    let backend = NativeTrainBackend::new();
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 40 } else { 300 };
    let spec = backend.growing_spec().clone();
    println!(
        "growing NCA, native train step: {}x{} grid, {} channels, hidden \
         {}, batch {}, {} worker threads",
        spec.height, spec.width, spec.channels, spec.hidden, spec.batch,
        backend.threads()
    );

    let cfg = TrainCfg { steps, seed: 0, log_every: 25, out_dir: None };
    let t = Timer::start();
    let (run, pool) = experiments::train_growing(&backend, &cfg, 64)?;
    let initial = run.history.values().first().copied().unwrap_or(0.0);
    let (_, last) = run.history.window_means(10);
    println!(
        "\ntrained {steps} steps in {:.1}s — loss {initial:.5} -> {last:.5} \
         ({} pool write-backs, mean slot age {:.1})",
        t.elapsed_secs(),
        pool.writes(),
        pool.mean_age()
    );

    // Grow from the seed with the trained parameters on the inference
    // backend — the params vector round-trips through the flat layout.
    let model = NcaModel::from_flat(spec.channels, spec.hidden, spec.dt,
                                    run.state.params.data());
    let seed_state = experiments::growing_seed(&backend)?;
    let native = NativeBackend::new();
    let batch = Tensor::stack(&[seed_state])?;
    let grown =
        native.rollout(&CaProgram::Nca(model), &batch, spec.rollout_max)?;
    let alpha: f32 = (0..spec.height)
        .flat_map(|y| (0..spec.width).map(move |x| (y, x)))
        .map(|(y, x)| grown.at(&[0, y, x, 3]))
        .sum::<f32>()
        / (spec.height * spec.width) as f32;
    println!(
        "grown from seed for {} steps: mean alpha {alpha:.3} (seed state \
         mean alpha {:.4})",
        spec.rollout_max,
        1.0 / (spec.height * spec.width) as f32
    );
    Ok(())
}
