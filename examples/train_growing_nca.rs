//! E10 — the end-to-end driver (App. B of the paper): train a growing NCA
//! from a single seed cell toward the lizard sprite with the sample-pool
//! recipe, log the loss curve, render growth frames, and verify the final
//! pattern.
//!
//! Backend-selectable: the default build trains hermetically on the
//! native BPTT backend and renders the growth strip through the native
//! NCA forward kernel (`CaProgram::Nca` from the trained parameters);
//! `--backend pjrt` drives the fused train-step + rollout artifacts
//! (needs `--features pjrt` + `make artifacts`). The training loop,
//! sample pool and loss bookkeeping are one code path through the
//! `ProgramBackend` trait.
//!
//!   cargo run --release --example train_growing_nca -- [--steps N]
//!       [--pool P] [--seed S] [--out DIR] [--backend native|pjrt]
//!
//! Writes out/growing_train_step.loss.csv, out/growing_growth.ppm
//! (development strip) and out/growing_train_step.params.bin.

use std::path::PathBuf;

use anyhow::{bail, Result};

use cax::backend::ProgramBackend;
use cax::coordinator::experiments;
use cax::coordinator::trainer::TrainCfg;
use cax::viz::ppm::Image;
use cax::viz::spacetime;
use cax::Tensor;

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

/// The chosen execution backend behind the shared `ProgramBackend`
/// contract.
fn backend(choice: &str) -> Result<Box<dyn ProgramBackend>> {
    match choice {
        "native" => {
            Ok(Box::new(cax::backend::NativeTrainBackend::new()))
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            use anyhow::Context;
            let artifacts = std::env::var("CAX_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".into());
            let engine =
                cax::runtime::Engine::load(std::path::Path::new(&artifacts))
                    .context("run `make artifacts` first")?;
            Ok(Box::new(engine))
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!(
            "this build has no pjrt feature; use --backend native or \
             rebuild with --features pjrt"
        ),
        other => bail!("unknown --backend {other:?} (native|pjrt)"),
    }
}

/// Development trajectory `[T, H, W, C]` of the trained cell, on
/// whichever backend is active. The native path forward-rolls exactly
/// `steps` updates and includes the seed state as frame 0
/// (`T = steps + 1`); the artifact path returns the `growing_rollout`
/// program's baked-in horizon, whose frame 0 is already one step
/// developed — callers must index by the returned `shape()[0]`, not by
/// `steps` (the rendering below does).
fn growth_trajectory(engine: &dyn ProgramBackend, choice: &str,
                     params: &Tensor, seed_state: Tensor, seed: u32,
                     steps: usize) -> Result<Tensor> {
    if choice == "native" {
        // Forward-roll the trained parameters through the native NCA
        // kernel, capturing every intermediate state.
        use cax::backend::native::nca::NcaModel;
        use cax::backend::native::train::NcaTrainSpec;
        use cax::backend::{Backend, CaProgram, NativeBackend};
        let spec = NcaTrainSpec::growing();
        let model = NcaModel::from_flat(spec.channels, spec.hidden, spec.dt,
                                        params.data());
        let backend = NativeBackend::new();
        let prog = CaProgram::Nca(model);
        let mut cur = Tensor::stack(&[seed_state])?;
        let mut frames = vec![cur.index_axis0(0)];
        for _ in 0..steps {
            cur = backend.rollout(&prog, &cur, 1)?;
            frames.push(cur.index_axis0(0));
        }
        return Tensor::stack(&frames);
    }
    // Artifact path: the fused rollout program records the trajectory.
    let mut out = engine.execute(
        "growing_rollout",
        &[cax::backend::Value::F32(params.clone()),
          cax::backend::Value::F32(seed_state),
          cax::backend::Value::U32(seed)],
    )?;
    Ok(out.pop().unwrap())
}

fn main() -> Result<()> {
    let steps: usize = arg("--steps").map(|s| s.parse()).transpose()?
        .unwrap_or(300);
    let pool_size: usize = arg("--pool").map(|s| s.parse()).transpose()?
        .unwrap_or(64);
    let seed: u32 = arg("--seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let out = PathBuf::from(arg("--out").unwrap_or_else(|| "out".into()));
    std::fs::create_dir_all(&out)?;
    let choice = arg("--backend").unwrap_or_else(|| {
        if cfg!(feature = "pjrt") { "pjrt".into() } else { "native".into() }
    });

    let engine = backend(&choice)?;
    let engine: &dyn ProgramBackend = engine.as_ref();

    println!("== growing NCA: {steps} train steps, pool {pool_size}, seed \
              {seed}, {choice} backend ==");
    let cfg = TrainCfg {
        steps,
        seed,
        log_every: 25,
        out_dir: Some(out.clone()),
    };
    let t = std::time::Instant::now();
    let (run, pool) = experiments::train_growing(engine, &cfg, pool_size)?;
    let secs = t.elapsed().as_secs_f64();
    let (first, last) = run.history.window_means(20);
    println!(
        "\ntrained in {secs:.1}s ({:.2} steps/s) — loss {first:.5} -> \
         {last:.5} ({}x reduction), pool mean age {:.1}",
        steps as f64 / secs,
        first / last.max(1e-12),
        pool.mean_age()
    );

    // Render the development trajectory of the trained NCA.
    let seed_state = experiments::growing_seed(engine)?;
    let traj = growth_trajectory(engine, &choice, &run.state.params,
                                 seed_state, seed, 32)?;
    let t_len = traj.shape()[0];
    let mut frames = Vec::new();
    for k in 0..6 {
        let i = (k * (t_len - 1)) / 5;
        frames.push(spacetime::render_rgba_state(&traj.index_axis0(i))?);
    }
    let strip = Image::hstrip(&frames, [255, 255, 255]);
    let strip_path = out.join("growing_growth.ppm");
    strip.upscale(4).write_ppm(&strip_path)?;

    // Verify against the target.
    let final_state = traj.index_axis0(t_len - 1);
    let target = experiments::growing_target(engine)?;
    let (h, w) = (target.shape()[0], target.shape()[1]);
    let mut mse = 0.0f64;
    for y in 0..h {
        for x in 0..w {
            for c in 0..4 {
                let d = final_state.at(&[y, x, c]) - target.at(&[y, x, c]);
                mse += (d as f64) * (d as f64);
            }
        }
    }
    mse /= (h * w * 4) as f64;
    println!("final RGBA MSE to target: {mse:.5}");
    println!("wrote {}, {}, {}", strip_path.display(),
             out.join("growing_train_step.loss.csv").display(),
             out.join("growing_train_step.params.bin").display());
    if last < first {
        println!("RESULT: OK — loss improved");
        Ok(())
    } else {
        anyhow::bail!("loss did not improve ({first:.5} -> {last:.5})")
    }
}
