//! E10 — the end-to-end driver (App. B of the paper): train a growing NCA
//! from a single seed cell toward the lizard sprite with the sample-pool
//! recipe, log the loss curve, render growth frames, and verify the final
//! pattern.
//!
//!   cargo run --release --example train_growing_nca -- [--steps N]
//!       [--pool P] [--seed S] [--out DIR]
//!
//! Writes out/growing_loss.csv, out/growing_growth.ppm (development strip)
//! and out/growing.params.bin. Recorded in EXPERIMENTS.md §E10.

use std::path::PathBuf;

use anyhow::{Context, Result};

use cax::coordinator::trainer::TrainCfg;
use cax::coordinator::experiments;
use cax::runtime::{Engine, Value};
use cax::viz::ppm::Image;
use cax::viz::spacetime;

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() -> Result<()> {
    let steps: usize = arg("--steps").map(|s| s.parse()).transpose()?
        .unwrap_or(300);
    let pool_size: usize = arg("--pool").map(|s| s.parse()).transpose()?
        .unwrap_or(64);
    let seed: u32 = arg("--seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let out = PathBuf::from(arg("--out").unwrap_or_else(|| "out".into()));
    std::fs::create_dir_all(&out)?;

    let artifacts = std::env::var("CAX_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let engine = Engine::load(std::path::Path::new(&artifacts))
        .context("run `make artifacts` first")?;

    println!("== growing NCA: {steps} train steps, pool {pool_size}, seed \
              {seed} ==");
    let cfg = TrainCfg {
        steps,
        seed,
        log_every: 25,
        out_dir: Some(out.clone()),
    };
    let t = std::time::Instant::now();
    let (run, pool) = experiments::train_growing(&engine, &cfg, pool_size)?;
    let secs = t.elapsed().as_secs_f64();
    let (first, last) = run.history.window_means(20);
    println!(
        "\ntrained in {secs:.1}s ({:.2} steps/s) — loss {first:.5} -> \
         {last:.5} ({}x reduction), pool mean age {:.1}",
        steps as f64 / secs,
        first / last.max(1e-12),
        pool.mean_age()
    );

    // Render the development trajectory of the trained NCA.
    let seed_state = experiments::growing_seed(&engine)?;
    let mut out_t = engine.execute(
        "growing_rollout",
        &[Value::F32(run.state.params.clone()), Value::F32(seed_state),
          Value::U32(seed)],
    )?;
    let traj = out_t.pop().unwrap(); // [T, H, W, C]
    let final_state = out_t.pop().unwrap();
    let t_len = traj.shape()[0];
    let mut frames = Vec::new();
    for k in 0..6 {
        let i = (k * (t_len - 1)) / 5;
        frames.push(spacetime::render_rgba_state(&traj.index_axis0(i))?);
    }
    let strip = Image::hstrip(&frames, [255, 255, 255]);
    let strip_path = out.join("growing_growth.ppm");
    strip.upscale(4).write_ppm(&strip_path)?;

    // Verify against the target.
    let target = experiments::growing_target(&engine)?;
    let (h, w) = (target.shape()[0], target.shape()[1]);
    let mut mse = 0.0f64;
    for y in 0..h {
        for x in 0..w {
            for c in 0..4 {
                let d = final_state.at(&[y, x, c]) - target.at(&[y, x, c]);
                mse += (d as f64) * (d as f64);
            }
        }
    }
    mse /= (h * w * 4) as f64;
    println!("final RGBA MSE to target: {mse:.5}");
    println!("wrote {}, {}, {}", strip_path.display(),
             out.join("growing_train_step.loss.csv").display(),
             out.join("growing_train_step.params.bin").display());
    if last < first {
        println!("RESULT: OK — loss improved");
        Ok(())
    } else {
        anyhow::bail!("loss did not improve ({first:.5} -> {last:.5})")
    }
}
