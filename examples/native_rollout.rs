//! Native-backend quickstart: the hermetic 60-second tour.
//!
//!   cargo run --release --example native_rollout
//!
//! Runs every Table-1 classic CA (ECA, Life, Lenia) plus a neural-CA
//! forward cell through `cax::backend::NativeBackend` — bit-packed SWAR
//! kernels, cache-tiled f32 kernels, batch-parallel worker pool — with
//! no artifacts, no XLA and no Python anywhere.

use anyhow::Result;

use cax::automata::lenia::LeniaParams;
use cax::automata::{LifeSim, WolframRule};
use cax::backend::native::nca::NcaModel;
use cax::backend::{Backend, CaProgram, NativeBackend};
use cax::coordinator::Simulator;
use cax::util::rng::Rng;
use cax::util::timer::Timer;

fn main() -> Result<()> {
    let backend = NativeBackend::new();
    let mut rng = Rng::new(0);
    println!("native backend up — {} worker threads\n", backend.threads());

    // 1. ECA rule 30: 32 rows of 1024 cells, 256 steps, bit-packed.
    let state = Simulator::random_binary_state(&[32, 1024], &mut rng);
    let prog = CaProgram::Eca { rule: WolframRule::new(30) };
    let t = Timer::start();
    let out = backend.rollout(&prog, &state, 256)?;
    println!(
        "eca    rule 30   32x1024   256 steps in {:>8.1} ms  ({:.2e} cell \
         updates/s, final mean {:.4})",
        t.elapsed_ms(),
        (state.numel() * 256) as f64 / t.elapsed_secs(),
        out.mean()
    );

    // 2. Life: gliders on a 256x256 torus — and the period-4 invariant.
    let gliders = LifeSim::gliders(8, 256, 256).to_tensor();
    let t = Timer::start();
    let out = backend.rollout(&CaProgram::Life, &gliders, 256)?;
    println!(
        "life   gliders   8x256x256 256 steps in {:>8.1} ms  ({:.2e} cell \
         updates/s, population {} per board)",
        t.elapsed_ms(),
        (gliders.numel() * 256) as f64 / t.elapsed_secs(),
        out.data().iter().sum::<f32>() / 8.0
    );

    // 3. Lenia: continuous CA, tiled sparse-tap convolution.
    let soup = Simulator::random_binary_state(&[4, 128, 128], &mut rng);
    let params = LeniaParams::default();
    let t = Timer::start();
    let out = backend.rollout(&CaProgram::Lenia { params }, &soup, 64)?;
    println!(
        "lenia  R={:<2}      4x128x128  64 steps in {:>8.1} ms  ({:.2e} cell \
         updates/s, mass {:.1})",
        params.radius,
        t.elapsed_ms(),
        (soup.numel() * 64) as f64 / t.elapsed_secs(),
        out.data().iter().sum::<f32>()
    );

    // 4. A neural-CA forward cell: depthwise perceive + per-cell MLP.
    let model = NcaModel::random(16, 64, &mut rng);
    let nca_state = Simulator::random_binary_state(&[4, 64, 64, 16],
                                                   &mut rng);
    let t = Timer::start();
    let out = backend.rollout(&CaProgram::Nca(model), &nca_state, 16)?;
    println!(
        "nca    16ch/64h  4x64x64    16 steps in {:>8.1} ms  (finite: {})",
        t.elapsed_ms(),
        out.data().iter().all(|v| v.is_finite())
    );

    println!("\nnext steps:");
    println!("  cax sim life --path native --render");
    println!("  cargo bench --bench fig3_native      # BENCH_native.json");
    println!("  cargo test                           # hermetic test suite");
    Ok(())
}
