//! Quickstart: the 60-second tour of the CAX-RS public API.
//!
//!   cargo run --release --example quickstart [-- --backend native|pjrt]
//!
//! Backend-selectable: the default build tours the hermetic native
//! backend (Table-1 registry, classic CAs on the bit-packed/tiled
//! kernels, a few native BPTT train steps with the sample pool — no
//! artifacts, no XLA, no Python). `--backend pjrt` tours the AOT
//! artifacts instead (needs `--features pjrt` + `make artifacts`).

use anyhow::Result;

use cax::automata::WolframRule;
use cax::coordinator::trainer::TrainCfg;
use cax::coordinator::{experiments, registry, Path, Simulator};
use cax::util::rng::Rng;
use cax::util::timer::Timer;

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() -> Result<()> {
    let choice = arg("--backend").unwrap_or_else(|| {
        if cfg!(feature = "pjrt") { "pjrt".into() } else { "native".into() }
    });
    match choice.as_str() {
        "native" => tour_native(),
        "pjrt" => tour_pjrt(),
        other => anyhow::bail!("unknown --backend {other:?} (native|pjrt)"),
    }
}

/// The hermetic tour: everything below runs on the default feature set.
fn tour_native() -> Result<()> {
    // 1. The Table-1 catalogue.
    println!("Table 1 registry:");
    for e in registry::table1() {
        println!("  {:<12} {:<46} {:<10} {}", e.key, e.label,
                 e.ca_type.name(), e.dimensions);
    }

    // 2. Classic CAs on the native bit-packed/tiled kernels.
    let sim = Simulator::native_only();
    let mut rng = Rng::new(0);
    println!("\nclassic CAs (native path, {} worker threads):",
             sim.native().threads());
    for ca in ["eca", "life", "lenia"] {
        let t = Timer::start();
        let (steps, out) = match ca {
            "eca" => {
                let state =
                    Simulator::random_binary_state(&[32, 1024], &mut rng);
                (256,
                 sim.run_eca(Path::Native, &state, WolframRule::new(30),
                             256)?)
            }
            "life" => {
                let state =
                    Simulator::random_binary_state(&[8, 256, 256], &mut rng);
                (256, sim.run_life(Path::Native, &state, 256)?)
            }
            _ => {
                let state =
                    Simulator::random_binary_state(&[4, 128, 128], &mut rng);
                (64, sim.run_lenia(Path::Native, &state, 64)?)
            }
        };
        println!("  {ca:<6} {steps:>4} steps in {:>8.1} ms  (mean state \
                  {:.4})", t.elapsed_ms(), out.mean());
    }

    // 3. A few native BPTT train steps (growing NCA + sample pool).
    println!("\ngrowing NCA — 10 native train steps with the sample pool:");
    let backend = cax::backend::NativeTrainBackend::new();
    let cfg = TrainCfg { steps: 10, seed: 0, log_every: 5, out_dir: None };
    let (run, pool) = experiments::train_growing(&backend, &cfg, 32)?;
    println!("  loss {:.5} -> {:.5}  (pool writes: {})",
             run.history.values()[0],
             run.history.last().unwrap(),
             pool.writes());

    println!("\nnext steps:");
    println!("  cax list / cax sim life --render / cax train growing");
    println!("  cax serve --port 7878    # multi-session HTTP service");
    println!("  cargo run --release --example quickstart -- --backend pjrt");
    Ok(())
}

/// The artifact tour (fused XLA rollouts through PJRT).
#[cfg(feature = "pjrt")]
fn tour_pjrt() -> Result<()> {
    use cax::runtime::Engine;

    // 1. Load the artifacts produced by `make artifacts`.
    let artifacts = std::env::var("CAX_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let engine = Engine::load(std::path::Path::new(&artifacts))?;
    println!("engine up on {} — {} artifacts\n", engine.platform(),
             engine.manifest().artifacts.len());

    // 2. Classic CAs on the fused path (one XLA program per rollout).
    let sim = Simulator::new(&engine);
    let mut rng = Rng::new(0);
    println!("classic CAs (fused path):");
    for (ca, artifact) in [("eca", "eca_rollout"), ("life", "life_rollout"),
                           ("lenia", "lenia_rollout")] {
        let steps = engine.manifest().artifact(artifact)?
            .meta_usize("steps").unwrap_or(64);
        let state = sim.random_state(artifact, &mut rng)?;
        let t = Timer::start();
        let out = match ca {
            "eca" => sim.run_eca(Path::Fused, &state, WolframRule::new(30),
                                 steps)?,
            "life" => sim.run_life(Path::Fused, &state, steps)?,
            _ => sim.run_lenia(Path::Fused, &state, steps)?,
        };
        println!("  {ca:<6} {steps:>4} steps in {:>8.1} ms  (mean state \
                  {:.4})", t.elapsed_ms(), out.mean());
    }

    // 3. A few NCA training steps (growing NCA + sample pool).
    println!("\ngrowing NCA — 10 fused train steps with the sample pool:");
    let cfg = TrainCfg { steps: 10, seed: 0, log_every: 5, out_dir: None };
    let (run, pool) = experiments::train_growing(&engine, &cfg, 32)?;
    println!("  loss {:.5} -> {:.5}  (pool writes: {})",
             run.history.values()[0],
             run.history.last().unwrap(),
             pool.writes());

    println!("\nnext steps:");
    println!("  cax list / cax sim life --render / cax train growing");
    println!("  cax-tables all --quick   # regenerate the paper's tables");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn tour_pjrt() -> Result<()> {
    anyhow::bail!(
        "this build has no pjrt feature; run with --backend native or \
         rebuild with --features pjrt"
    )
}
