//! Quickstart: the 60-second tour of the CAX-RS public API.
//!
//!   cargo run --release --example quickstart
//!
//! Loads the AOT artifacts, lists the Table-1 registry, runs each classic
//! CA on the fused path, and takes a handful of NCA training steps —
//! everything a new user needs to see to know the stack is alive.

use anyhow::Result;

use cax::automata::WolframRule;
use cax::coordinator::trainer::TrainCfg;
use cax::coordinator::{experiments, registry, Path, Simulator};
use cax::runtime::Engine;
use cax::util::rng::Rng;
use cax::util::timer::Timer;

fn main() -> Result<()> {
    // 1. Load the artifacts produced by `make artifacts`.
    let artifacts = std::env::var("CAX_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let engine = Engine::load(std::path::Path::new(&artifacts))?;
    println!("engine up on {} — {} artifacts\n", engine.platform(),
             engine.manifest().artifacts.len());

    // 2. The Table-1 catalogue.
    println!("Table 1 registry:");
    for e in registry::table1() {
        println!("  {:<12} {:<46} {:<10} {}", e.key, e.label,
                 e.ca_type.name(), e.dimensions);
    }

    // 3. Classic CAs on the fused path (one XLA program per rollout).
    let sim = Simulator::new(&engine);
    let mut rng = Rng::new(0);
    println!("\nclassic CAs (fused path):");
    for (ca, artifact) in [("eca", "eca_rollout"), ("life", "life_rollout"),
                           ("lenia", "lenia_rollout")] {
        let steps = engine.manifest().artifact(artifact)?
            .meta_usize("steps").unwrap_or(64);
        let state = sim.random_state(artifact, &mut rng)?;
        let t = Timer::start();
        let out = match ca {
            "eca" => sim.run_eca(Path::Fused, &state, WolframRule::new(30),
                                 steps)?,
            "life" => sim.run_life(Path::Fused, &state, steps)?,
            _ => sim.run_lenia(Path::Fused, &state, steps)?,
        };
        println!("  {ca:<6} {steps:>4} steps in {:>8.1} ms  (mean state \
                  {:.4})", t.elapsed_ms(), out.mean());
    }

    // 4. A few NCA training steps (growing NCA + sample pool).
    println!("\ngrowing NCA — 10 fused train steps with the sample pool:");
    let cfg = TrainCfg { steps: 10, seed: 0, log_every: 5, out_dir: None };
    let (run, pool) = experiments::train_growing(&engine, &cfg, 32)?;
    println!("  loss {:.5} -> {:.5}  (pool writes: {})",
             run.history.values()[0],
             run.history.last().unwrap(),
             pool.writes());

    println!("\nnext steps:");
    println!("  cax list / cax sim life --render / cax train growing");
    println!("  cax-tables all --quick   # regenerate the paper's tables");
    Ok(())
}
