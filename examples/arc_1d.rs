//! E5/E9 — §5.3: train a 1D-ARC NCA on one task and watch it "reason":
//! prints the space-time evolution as colored text and saves the Fig. 8
//! diagram, then reports exact-match accuracy vs the paper's GPT-4 row.
//!
//! Backend-selectable: the default build trains hermetically on the
//! native BPTT backend (no artifacts, no XLA, no network); `--backend
//! pjrt` drives the fused XLA artifacts instead (needs `--features
//! pjrt` + `make artifacts`). Everything below the backend choice is
//! one code path through the `ProgramBackend` trait.
//!
//!   cargo run --release --example arc_1d -- [--task move-1] [--steps N]
//!       [--seed S] [--out DIR] [--backend native|pjrt]

use std::path::PathBuf;

use anyhow::{bail, Result};

use cax::backend::{NativeTrainBackend, ProgramBackend, Value};
use cax::coordinator::trainer::TrainCfg;
use cax::coordinator::{evaluator, experiments};
use cax::datasets::arc1d::{one_hot_batch, Task};
use cax::viz::spacetime;

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

/// The chosen execution backend behind the shared `ProgramBackend`
/// contract.
fn backend(choice: &str) -> Result<Box<dyn ProgramBackend>> {
    match choice {
        "native" => Ok(Box::new(NativeTrainBackend::new())),
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            use anyhow::Context;
            let artifacts = std::env::var("CAX_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".into());
            let engine =
                cax::runtime::Engine::load(std::path::Path::new(&artifacts))
                    .context("run `make artifacts` first")?;
            Ok(Box::new(engine))
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!(
            "this build has no pjrt feature; use --backend native or \
             rebuild with --features pjrt"
        ),
        other => bail!("unknown --backend {other:?} (native|pjrt)"),
    }
}

fn main() -> Result<()> {
    let task_name = arg("--task").unwrap_or_else(|| "move-1".into());
    let steps: usize =
        arg("--steps").map(|s| s.parse()).transpose()?.unwrap_or(300);
    let seed: u64 = arg("--seed").map(|s| s.parse()).transpose()?.unwrap_or(7);
    let out = PathBuf::from(arg("--out").unwrap_or_else(|| "out".into()));
    std::fs::create_dir_all(&out)?;
    let choice = arg("--backend").unwrap_or_else(|| {
        if cfg!(feature = "pjrt") { "pjrt".into() } else { "native".into() }
    });

    let Some(task) = Task::find(&task_name) else {
        bail!(
            "unknown task {task_name:?}; available: {}",
            Task::ALL
                .iter()
                .map(|t| t.slug())
                .collect::<Vec<_>>()
                .join(", ")
        );
    };

    let engine = backend(&choice)?;
    let engine: &dyn ProgramBackend = engine.as_ref();

    println!(
        "== 1D-ARC NCA on {:?} ({} train steps, {} backend) ==",
        task.name(), steps, choice
    );
    let (train_set, test_set) =
        experiments::arc_split(engine, task, 160, 50, seed)?;
    let cfg = TrainCfg { steps, seed: seed as u32, log_every: 25,
                         out_dir: None };
    let run = experiments::train_arc(engine, &cfg, task, &train_set)?;

    // Evaluate: the paper's exact-match criterion.
    let acc = evaluator::arc_accuracy(engine, &run.state.params, &test_set)?;
    let pix =
        evaluator::arc_pixel_accuracy(engine, &run.state.params, &test_set)?;
    println!(
        "\n{}: exact-match {:.1}%  per-pixel {:.1}%  (paper NCA {:.0}%, \
         GPT-4 {:.0}%)",
        task.name(), 100.0 * acc, 100.0 * pix, task.paper_nca_accuracy(),
        task.gpt4_accuracy()
    );

    // Space-time diagram of one held-out example (Fig. 8).
    let info = engine.manifest().artifact("arc_traj")?;
    let w = info.inputs[1].shape[0];
    let e = &test_set[0];
    let input1h =
        one_hot_batch(&[e.input.as_slice()], w).index_axis0(0);
    let o = engine.execute(
        "arc_traj",
        &[Value::F32(run.state.params.clone()), Value::F32(input1h)],
    )?;
    let traj = &o[0]; // [T, W, COLORS]

    // Terminal rendering: input row, a few intermediate rows, output row.
    let glyph = |c: u8| match c {
        0 => ' ',
        c => (b'0' + c) as char,
    };
    let row_str = |row: &[u8]| -> String {
        row.iter().map(|&c| glyph(c)).collect()
    };
    println!("\ninput  |{}|", row_str(&e.input));
    let t_len = traj.shape()[0];
    for k in [t_len / 4, t_len / 2, 3 * t_len / 4] {
        let frame = traj.index_axis0(k);
        let pred = cax::datasets::arc1d::argmax_colors(
            &cax::Tensor::stack(&[frame])?,
        );
        println!("t={k:<4} |{}|", row_str(&pred[0]));
    }
    let last = traj.index_axis0(t_len - 1);
    let pred =
        cax::datasets::arc1d::argmax_colors(&cax::Tensor::stack(&[last])?);
    println!("output |{}|", row_str(&pred[0]));
    println!("target |{}|", row_str(&e.target));

    let img = spacetime::render_spacetime_arc(traj)?;
    let path = out.join(format!("fig8_{}.ppm", task.slug()));
    img.upscale(6).write_ppm(&path)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
