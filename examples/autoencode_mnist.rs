//! E8 — §5.2 Self-autoencoding MNIST digits in a 3D NCA: the digit is
//! painted on one face of a 3D grid; a masked wall with a single-cell hole
//! separates it from the opposite face; one uniform local rule must encode,
//! squeeze through the bottleneck, and decode.
//!
//!   cargo run --release --features pjrt --example autoencode_mnist --
//!       [--steps N] [--seed S] [--out DIR]
//!
//! Writes out/fig7_reconstructions.ppm (originals over reconstructions,
//! the paper's Fig. 7 strip) and prints reconstruction MSE.
//!
//! **pjrt-gated** (`required-features`): the 3D autoencoder scenario
//! (`autoenc3d_train_step` / `autoenc3d_eval`) has no native
//! implementation — the native train backend covers growing, MNIST and
//! 1D-ARC only. See the examples table in `rust/README.md`.

use std::path::PathBuf;

use anyhow::{Context, Result};

use cax::coordinator::trainer::TrainCfg;
use cax::coordinator::{evaluator, experiments};
use cax::datasets::mnist::{self, MnistConfig};
use cax::runtime::{Engine, Value};
use cax::viz::colormap;
use cax::viz::ppm::Image;

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() -> Result<()> {
    let steps: usize =
        arg("--steps").map(|s| s.parse()).transpose()?.unwrap_or(400);
    let seed: u32 = arg("--seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let out = PathBuf::from(arg("--out").unwrap_or_else(|| "out".into()));
    std::fs::create_dir_all(&out)?;

    let artifacts = std::env::var("CAX_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let engine = Engine::load(std::path::Path::new(&artifacts))
        .context("run `make artifacts` first")?;

    let info = engine.manifest().artifact("autoenc3d_eval")?;
    let (b, h, w) = (info.inputs[1].shape[0], info.inputs[1].shape[1],
                     info.inputs[1].shape[2]);
    let depth = info.meta_usize("depth").unwrap_or(0);
    println!("== 3D self-autoencoding NCA: {h}x{w} faces, depth {depth}, \
              1-cell bottleneck, {steps} train steps ==");

    let cfg = TrainCfg { steps, seed, log_every: 25,
                         out_dir: Some(out.clone()) };
    let run = experiments::train_autoenc3d(&engine, &cfg)?;
    let (first, last) = run.history.window_means(20);
    println!("loss {first:.5} -> {last:.5}");

    // Held-out digits -> Fig. 7 strip (top originals, bottom recon).
    let digits = mnist::dataset(b, &MnistConfig::for_grid(h, w),
                                seed as u64 ^ 0x77);
    let refs: Vec<&mnist::Digit> = digits.iter().collect();
    let batch = mnist::batch_images(&refs);
    let o = engine.execute(
        "autoenc3d_eval",
        &[Value::F32(run.state.params.clone()), Value::F32(batch.clone()),
          Value::U32(seed)],
    )?;
    let recon = &o[0]; // [B, H, W]

    let render = |img: &cax::Tensor| {
        let mut im = Image::new(w, h);
        for y in 0..h {
            for x in 0..w {
                im.set(y, x, colormap::gray(img.at(&[y, x])));
            }
        }
        im
    };
    let top: Vec<Image> =
        (0..b).map(|i| render(&batch.index_axis0(i))).collect();
    let bot: Vec<Image> =
        (0..b).map(|i| render(&recon.index_axis0(i))).collect();
    let top_strip = Image::hstrip(&top, [255, 0, 0]);
    let bot_strip = Image::hstrip(&bot, [255, 0, 0]);
    // Stack the two strips vertically with a divider row.
    let mut fig = Image::new(top_strip.width, top_strip.height * 2 + 1);
    for y in 0..top_strip.height {
        for x in 0..top_strip.width {
            fig.set(y, x, top_strip.get(y, x));
            fig.set(top_strip.height + 1 + y, x, bot_strip.get(y, x));
        }
    }
    for x in 0..fig.width {
        fig.set(top_strip.height, x, [255, 0, 0]);
    }
    let path = out.join("fig7_reconstructions.ppm");
    fig.upscale(6).write_ppm(&path)?;

    let mse = evaluator::autoenc3d_recon_mse(&engine, &run.state.params,
                                             &refs, seed)?;
    println!("reconstruction MSE on held-out digits: {mse:.5}");
    println!("wrote {}", path.display());

    // A baseline for context: MSE of predicting all-zeros.
    let zeros = cax::Tensor::zeros(&[h, w]);
    let mut zero_mse = 0.0;
    for i in 0..b {
        zero_mse += batch.index_axis0(i).mse(&zeros)? as f64;
    }
    zero_mse /= b as f64;
    println!("(all-zeros baseline MSE: {zero_mse:.5} — the NCA must beat \
              this to be transmitting information)");
    if mse < zero_mse {
        println!("RESULT: OK — information crossed the bottleneck");
    }
    Ok(())
}
