//! E6/E7 — §5.1 Diffusing NCA: train the denoising NCA (no sample pool),
//! render the noise→pattern sequence of Fig. 4, then run the Fig. 5
//! damage/regeneration comparison against a growing NCA.
//!
//!   cargo run --release --features pjrt --example diffusing_nca --
//!       [--steps N] [--seed S] [--out DIR] [--skip-fig5]
//!
//! **pjrt-gated** (`required-features`): the diffusing scenario
//! (`diffusing_train_step` / `diffusing_rollout`) and the Fig. 5 damage
//! protocol run on artifact programs with no native equivalent yet.
//! See the examples table in `rust/README.md`.

use std::path::PathBuf;

use anyhow::{Context, Result};

use cax::coordinator::trainer::TrainCfg;
use cax::coordinator::damage::{self, DamageMode};
use cax::coordinator::experiments;
use cax::datasets::targets::Sprite;
use cax::runtime::{Engine, Value};
use cax::viz::ppm::Image;
use cax::viz::spacetime;

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() -> Result<()> {
    let steps: usize =
        arg("--steps").map(|s| s.parse()).transpose()?.unwrap_or(300);
    let seed: u32 = arg("--seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let out = PathBuf::from(arg("--out").unwrap_or_else(|| "out".into()));
    let skip_fig5 = std::env::args().any(|a| a == "--skip-fig5");
    std::fs::create_dir_all(&out)?;

    let artifacts = std::env::var("CAX_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let engine = Engine::load(std::path::Path::new(&artifacts))
        .context("run `make artifacts` first")?;

    // ---- Fig. 4: train the diffusing NCA and render denoising frames.
    println!("== diffusing NCA: {steps} train steps (NO sample pool) ==");
    let cfg = TrainCfg { steps, seed, log_every: 25,
                         out_dir: Some(out.clone()) };
    let run = experiments::train_diffusing(&engine, &cfg)?;
    let (first, last) = run.history.window_means(20);
    println!("loss {first:.5} -> {last:.5}");

    let info = engine.manifest().artifact("diffusing_rollout")?;
    let shape = info.inputs[1].shape.clone();
    // RGBA noise, hidden channels zero — the training distribution.
    let noise = experiments::diffusing_noise_state(&engine, seed as u64)?;
    let mut o = engine.execute(
        "diffusing_rollout",
        &[Value::F32(run.state.params.clone()), Value::F32(noise),
          Value::U32(seed)],
    )?;
    let traj = o.pop().unwrap();
    let t_len = traj.shape()[0];
    let mut frames = Vec::new();
    for k in 0..6 {
        let i = (k * (t_len - 1)) / 5;
        frames.push(spacetime::render_rgba_state(&traj.index_axis0(i))?);
    }
    let fig4 = out.join("fig4_denoise.ppm");
    Image::hstrip(&frames, [255, 255, 255]).upscale(4).write_ppm(&fig4)?;
    println!("wrote {} (noise -> pattern, the Fig. 4 sequence)",
             fig4.display());

    if skip_fig5 {
        return Ok(());
    }

    // ---- Fig. 5: damage both NCA kinds, compare recovery.
    println!("\n== Fig. 5: damage / regeneration (growing vs diffusing) ==");
    let (grow_run, _pool) = experiments::train_growing(&engine, &cfg, 64)?;
    let seed_state = experiments::growing_seed(&engine)?;
    let ginfo = engine.manifest().artifact("growing_rollout")?;
    let gshape = &ginfo.inputs[1].shape;
    let gtarget = Sprite::Lizard.render(gshape[0], gshape[1]);
    let grow = damage::run_damage_trial(
        &engine, "growing_rollout", &grow_run.state.params, seed_state,
        &gtarget, 3, 3, false, DamageMode::Noise, seed,
    )?;

    let dtarget = Sprite::Lizard.render(shape[0], shape[1]);
    let mixed =
        experiments::diffusing_mixed_state(&engine, &dtarget, 0.4,
                                           seed as u64 + 1)?;
    let diff = damage::run_damage_trial(
        &engine, "diffusing_rollout", &run.state.params, mixed, &dtarget,
        1, 2, true, DamageMode::Noise, seed,
    )?;

    println!("{:<12} {:>12} {:>12} {:>12} {:>9}", "NCA", "pre-dmg",
             "post-dmg", "recovered", "healed");
    for (name, r) in [("growing", &grow), ("diffusing", &diff)] {
        println!("{:<12} {:>12.5} {:>12.5} {:>12.5} {:>8.0}%", name,
                 r.pre_damage_mse, r.post_damage_mse, r.recovered_mse,
                 100.0 * r.recovery_fraction());
    }
    println!("(paper: diffusing NCAs regenerate; growing NCAs are unstable \
              unless trained for it)");
    Ok(())
}
